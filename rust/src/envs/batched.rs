//! `BatchedEnv` — N independently-seeded instances of any [`Env`] stepped
//! in lockstep, the actor-fleet side of the batched rollout path.
//!
//! Each lane owns its env plus a private RNG stream, so the fleet is a
//! pure function of the lane seeds: lane `l` of a `BatchedEnv` replays
//! exactly the stream of a standalone env driven with the same RNG
//! (asserted for all 8 env combos in `tests/envs.rs`).  Lanes that
//! finish an episode auto-reset, so [`BatchedEnv::obs`] always holds a
//! live observation per lane and the agent never sees a terminal state
//! as input.  Stepping fans out over `exec::pool` (envs run on the PS
//! side of the paper's mapping — CPU threads are the right substrate),
//! while collection into the flat lane-major buffers stays sequential
//! and allocation-free.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, ensure, Result};

use super::{Action, Env, Transition};
use crate::exec::Pool;
use crate::util::json::{hex_f32s, hex_f64s, hex_u64, parse_hex_f32s, parse_hex_f64s, Json};
use crate::util::Rng;

/// Fork `n` per-lane RNG streams off a master RNG.  Lane 0 is the first
/// fork with `tag`, so at `n == 1` this is bit-identical to the scalar
/// path's single `rng.fork(tag)` — the seeding half of the `--actors 1`
/// bit-identity guarantee.
pub fn lane_rngs(rng: &mut Rng, tag: u64, n: usize) -> Vec<Rng> {
    (0..n).map(|l| rng.fork(tag.wrapping_add(l as u64))).collect()
}

/// One lane: an env, its RNG stream, and the latest raw transition.
struct Lane {
    env: Box<dyn Env>,
    rng: Rng,
    /// Current observation fed to the agent next round (post-auto-reset).
    cur: Vec<f32>,
    /// Raw outcome of the last step (pre-auto-reset `obs`).
    tr: Transition,
}

/// N env lanes stepped in lockstep with per-lane auto-reset.
pub struct BatchedEnv {
    lanes: Vec<Mutex<Lane>>,
    obs_dim: usize,
    action_dim: usize,
    discrete: bool,
    pool: Arc<Pool>,
    obs: Vec<f32>,
    next_obs: Vec<f32>,
    rewards: Vec<f64>,
    dones: Vec<bool>,
}

impl BatchedEnv {
    /// Build a fleet from pre-seeded lanes and reset each one.  All envs
    /// must agree on dims/action kind; lanes reset in order, so lane
    /// RNG states after construction match the scalar `reset` path.
    pub fn new(envs: Vec<Box<dyn Env>>, rngs: Vec<Rng>, pool: Arc<Pool>) -> Result<BatchedEnv> {
        ensure!(!envs.is_empty(), "BatchedEnv needs at least one lane");
        ensure!(
            envs.len() == rngs.len(),
            "BatchedEnv: {} envs but {} lane RNGs",
            envs.len(),
            rngs.len()
        );
        let obs_dim = envs[0].obs_dim();
        let action_dim = envs[0].action_dim();
        let discrete = envs[0].is_discrete();
        for e in &envs {
            ensure!(
                e.obs_dim() == obs_dim
                    && e.action_dim() == action_dim
                    && e.is_discrete() == discrete,
                "BatchedEnv lanes must be homogeneous (obs_dim/action_dim/action kind)"
            );
        }
        let n = envs.len();
        let mut lanes = Vec::with_capacity(n);
        let mut obs = Vec::with_capacity(n * obs_dim);
        for (mut env, mut rng) in envs.into_iter().zip(rngs) {
            let cur = env.reset(&mut rng);
            ensure!(
                cur.len() == obs_dim,
                "env reset returned {} values, expected {obs_dim}",
                cur.len()
            );
            obs.extend_from_slice(&cur);
            lanes.push(Mutex::new(Lane {
                env,
                rng,
                cur,
                tr: Transition { obs: Vec::new(), reward: 0.0, done: false },
            }));
        }
        Ok(BatchedEnv {
            lanes,
            obs_dim,
            action_dim,
            discrete,
            pool,
            obs,
            next_obs: vec![0.0; n * obs_dim],
            rewards: vec![0.0; n],
            dones: vec![false; n],
        })
    }

    /// Step every lane with its action; done lanes auto-reset.  After the
    /// call, [`obs`](Self::obs) holds next-round inputs (reset obs where
    /// done), while [`next_obs`](Self::next_obs) / [`rewards`](Self::rewards)
    /// / [`dones`](Self::dones) hold the raw transition for `observe`.
    pub fn step(&mut self, actions: &[Action]) -> Result<()> {
        ensure!(
            actions.len() == self.lanes.len(),
            "BatchedEnv::step: {} actions for {} lanes",
            actions.len(),
            self.lanes.len()
        );
        let _span = crate::obs::trace::span(
            crate::obs::trace::Kernel::EnvStep,
            [self.lanes.len(), 0, 0],
            self.pool.threads(),
        );
        // Validate the action kind up-front so a mis-wired env/agent
        // combo fails with a clear error, not a panic inside a worker.
        for (l, a) in actions.iter().enumerate() {
            if self.discrete {
                a.try_discrete().map_err(|e| anyhow!("lane {l}: {e}"))?;
            } else {
                a.try_continuous().map_err(|e| anyhow!("lane {l}: {e}"))?;
            }
        }
        let lanes = &self.lanes;
        let task = |l: usize| {
            let mut guard = lanes[l].lock().expect("lane mutex poisoned");
            let lane = &mut *guard;
            let tr = lane.env.step(&actions[l], &mut lane.rng);
            if tr.done {
                lane.cur = lane.env.reset(&mut lane.rng);
            } else {
                lane.cur.clone_from(&tr.obs);
            }
            lane.tr = tr;
        };
        self.pool.run(lanes.len(), &task);
        let d = self.obs_dim;
        for (l, m) in self.lanes.iter().enumerate() {
            let lane = m.lock().expect("lane mutex poisoned");
            self.next_obs[l * d..(l + 1) * d].copy_from_slice(&lane.tr.obs);
            self.obs[l * d..(l + 1) * d].copy_from_slice(&lane.cur);
            self.rewards[l] = lane.tr.reward;
            self.dones[l] = lane.tr.done;
        }
        Ok(())
    }

    /// Lane count N.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn action_dim(&self) -> usize {
        self.action_dim
    }

    pub fn is_discrete(&self) -> bool {
        self.discrete
    }

    /// Current per-lane observations (N × obs_dim, lane-major) — the
    /// agent's next `act` input; reset obs where a lane just finished.
    pub fn obs(&self) -> &[f32] {
        &self.obs
    }

    /// Raw post-step observations of the last step (pre-auto-reset),
    /// the `next_obs` argument to `Agent::observe`.
    pub fn next_obs(&self) -> &[f32] {
        &self.next_obs
    }

    pub fn rewards(&self) -> &[f64] {
        &self.rewards
    }

    pub fn dones(&self) -> &[bool] {
        &self.dones
    }

    /// Snapshot every lane — env state, RNG stream position and current
    /// observation — at a step boundary.  The raw transition buffers
    /// (`next_obs`/`rewards`/`dones`) are deliberately excluded: they are
    /// consumed by `observe` before a checkpoint is taken and fully
    /// overwritten by the next [`BatchedEnv::step`].
    pub fn save_state(&self) -> Json {
        let lanes: Vec<Json> = self
            .lanes
            .iter()
            .map(|m| {
                let lane = m.lock().expect("lane mutex poisoned");
                let (state, spare) = lane.rng.state_parts();
                let mut pairs = vec![
                    ("env", lane.env.save_state()),
                    ("rng", Json::Str(hex_u64(state))),
                    ("cur", Json::Str(hex_f32s(&lane.cur))),
                ];
                if let Some(sp) = spare {
                    pairs.push(("rng_spare", Json::Str(hex_f64s(&[sp]))));
                }
                Json::obj(pairs)
            })
            .collect();
        Json::Arr(lanes)
    }

    /// Restore a [`BatchedEnv::save_state`] snapshot into a freshly-built
    /// fleet of the same shape, rebuilding the `obs` buffer so the next
    /// `act` sees exactly what the snapshotted fleet would have fed it.
    pub fn restore_state(&mut self, state: &Json) -> Result<()> {
        let arr = state.as_arr().ok_or_else(|| anyhow!("fleet state: expected an array"))?;
        ensure!(
            arr.len() == self.lanes.len(),
            "fleet state: snapshot has {} lanes, fleet has {}",
            arr.len(),
            self.lanes.len()
        );
        let d = self.obs_dim;
        for (l, saved) in arr.iter().enumerate() {
            let mut lane = self.lanes[l].lock().expect("lane mutex poisoned");
            lane.env.restore_state(saved.req("env")?)?;
            let spare = match saved.get("rng_spare") {
                Some(j) => {
                    let s =
                        j.as_str().ok_or_else(|| anyhow!("fleet state: bad rng_spare"))?;
                    let v = parse_hex_f64s(s)?;
                    ensure!(v.len() == 1, "fleet state: bad rng_spare length");
                    Some(v[0])
                }
                None => None,
            };
            lane.rng = Rng::from_parts(saved.req_u64_hex("rng")?, spare);
            let cur = parse_hex_f32s(saved.req_str("cur")?)?;
            ensure!(cur.len() == d, "fleet state: lane {l} has a bad obs length");
            self.obs[l * d..(l + 1) * d].copy_from_slice(&cur);
            lane.cur = cur;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::CartPole;

    fn fleet(n: usize) -> BatchedEnv {
        let envs: Vec<Box<dyn Env>> =
            (0..n).map(|_| Box::new(CartPole::new()) as Box<dyn Env>).collect();
        let mut root = Rng::new(42);
        let rngs = lane_rngs(&mut root, 0xE74, n);
        BatchedEnv::new(envs, rngs, Pool::global()).expect("fleet")
    }

    #[test]
    fn lane0_matches_scalar_env() {
        let mut benv = fleet(3);
        let mut env = CartPole::new();
        let mut root = Rng::new(42);
        let mut rng = root.fork(0xE74);
        let mut cur = env.reset(&mut rng);
        assert_eq!(benv.obs()[..4], cur[..]);
        for _ in 0..50 {
            let actions = vec![Action::Discrete(1), Action::Discrete(0), Action::Discrete(1)];
            benv.step(&actions).expect("step");
            let tr = env.step(&actions[0], &mut rng);
            assert_eq!(benv.next_obs()[..4], tr.obs[..]);
            assert_eq!(benv.rewards()[0], tr.reward);
            assert_eq!(benv.dones()[0], tr.done);
            cur = if tr.done { env.reset(&mut rng) } else { tr.obs };
            assert_eq!(benv.obs()[..4], cur[..]);
        }
    }

    #[test]
    fn miswired_action_kind_is_a_clean_error() {
        let mut benv = fleet(2);
        let err = benv
            .step(&[Action::Discrete(0), Action::Continuous(vec![0.5])])
            .expect_err("continuous action into CartPole must fail");
        let msg = format!("{err}");
        assert!(msg.contains("lane 1"), "{msg}");
        assert!(msg.contains("discrete"), "{msg}");
    }

    #[test]
    fn wrong_action_count_is_an_error() {
        let mut benv = fleet(2);
        assert!(benv.step(&[Action::Discrete(0)]).is_err());
    }

    #[test]
    fn fleet_snapshot_resumes_bit_identically() {
        // MsPacman's ghost consumes lane RNG every step, so this covers
        // env state + RNG stream + current-obs restoration together.
        use crate::envs::MiniMsPacman;
        let make = || {
            let envs: Vec<Box<dyn Env>> =
                (0..3).map(|_| Box::new(MiniMsPacman::mini()) as Box<dyn Env>).collect();
            let mut root = Rng::new(9);
            let rngs = lane_rngs(&mut root, 0xE74, 3);
            BatchedEnv::new(envs, rngs, Pool::global()).expect("fleet")
        };
        let mut a = make();
        for k in 0..17usize {
            let actions: Vec<Action> = (0..3).map(|l| Action::Discrete((k + l) % 9)).collect();
            a.step(&actions).expect("step");
        }
        let snap = a.save_state();
        let mut b = make();
        b.restore_state(&snap).expect("restore");
        assert_eq!(a.obs(), b.obs(), "restored fleet must feed identical next obs");
        for k in 0..29usize {
            let actions: Vec<Action> =
                (0..3).map(|l| Action::Discrete((2 * k + l) % 9)).collect();
            a.step(&actions).expect("step a");
            b.step(&actions).expect("step b");
            assert_eq!(a.obs(), b.obs(), "obs diverged at step {k}");
            assert_eq!(a.next_obs(), b.next_obs(), "next_obs diverged at step {k}");
            assert_eq!(a.rewards(), b.rewards(), "rewards diverged at step {k}");
            assert_eq!(a.dones(), b.dones(), "dones diverged at step {k}");
        }
        // Shape mismatch is a clean error, not a silent partial restore.
        let mut small = fleet(2);
        assert!(small.restore_state(&snap).is_err());
    }
}
