//! MountainCarContinuous-v0 dynamics (Gym constants): an under-powered
//! car must build momentum to escape a valley.  Reward: +100 at the goal
//! minus action energy.

use anyhow::{ensure, Result};

use crate::util::json::{hex_f64s, parse_hex_f64s, Json};
use crate::util::Rng;

use super::{Action, Env, Transition};

const MIN_POS: f64 = -1.2;
const MAX_POS: f64 = 0.6;
const MAX_SPEED: f64 = 0.07;
const GOAL_POS: f64 = 0.45;
const POWER: f64 = 0.0015;

#[derive(Clone, Debug, Default)]
pub struct MountainCarCont {
    pos: f64,
    vel: f64,
    steps: usize,
}

impl MountainCarCont {
    pub fn new() -> Self {
        Self::default()
    }

    fn obs(&self) -> Vec<f32> {
        vec![self.pos as f32, self.vel as f32]
    }
}

impl Env for MountainCarCont {
    fn obs_dim(&self) -> usize {
        2
    }

    fn action_dim(&self) -> usize {
        1
    }

    fn is_discrete(&self) -> bool {
        false
    }

    fn max_steps(&self) -> usize {
        999
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.pos = rng.uniform_in(-0.6, -0.4);
        self.vel = 0.0;
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Transition {
        let force = (action.continuous()[0] as f64).clamp(-1.0, 1.0);
        self.vel += force * POWER - 0.0025 * (3.0 * self.pos).cos();
        self.vel = self.vel.clamp(-MAX_SPEED, MAX_SPEED);
        self.pos = (self.pos + self.vel).clamp(MIN_POS, MAX_POS);
        if self.pos <= MIN_POS && self.vel < 0.0 {
            self.vel = 0.0;
        }
        self.steps += 1;
        let reached = self.pos >= GOAL_POS;
        let truncated = self.steps >= self.max_steps();
        let reward = if reached { 100.0 } else { 0.0 } - 0.1 * force * force;
        Transition { obs: self.obs(), reward, done: reached || truncated }
    }

    fn save_state(&self) -> Json {
        Json::obj(vec![
            ("phase", Json::Str(hex_f64s(&[self.pos, self.vel]))),
            ("steps", Json::Num(self.steps as f64)),
        ])
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        let p = parse_hex_f64s(state.req_str("phase")?)?;
        ensure!(p.len() == 2, "mountain-car state: expected 2 phase values, got {}", p.len());
        self.pos = p[0];
        self.vel = p[1];
        self.steps = state.req_u64("steps")? as usize;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::contract_check;

    #[test]
    fn contract() {
        contract_check(&mut MountainCarCont::new(), 21);
    }

    #[test]
    fn full_throttle_alone_cannot_climb() {
        // The car is under-powered by construction: constant +1 from the
        // valley floor must not reach the goal directly.
        let mut env = MountainCarCont::new();
        let mut rng = Rng::new(3);
        env.reset(&mut rng);
        env.pos = -0.5;
        env.vel = 0.0;
        let mut reached = false;
        for _ in 0..200 {
            let t = env.step(&Action::Continuous(vec![1.0]), &mut rng);
            if t.done && env.pos >= GOAL_POS {
                reached = true;
                break;
            }
        }
        assert!(!reached, "car must be under-powered");
    }

    #[test]
    fn energy_pumping_escapes() {
        // Bang-bang in the direction of motion builds energy and escapes.
        let mut env = MountainCarCont::new();
        let mut rng = Rng::new(4);
        let mut obs = env.reset(&mut rng);
        let mut reached = false;
        for _ in 0..999 {
            let a = if obs[1] >= 0.0 { 1.0 } else { -1.0 };
            let t = env.step(&Action::Continuous(vec![a]), &mut rng);
            obs = t.obs;
            if t.done {
                reached = obs[0] >= GOAL_POS as f32;
                break;
            }
        }
        assert!(reached, "energy pumping should escape the valley");
    }
}
