//! LunarLanderContinuous: simplified 2-D rigid-body lander (DESIGN.md
//! §Substitutions — Box2D replaced by explicit dynamics with the same
//! state/action interface and reward shaping as the Gym task).
//!
//! State (8): x, y, ẋ, ẏ, θ, θ̇, left-leg contact, right-leg contact.
//! Actions (2, continuous): main engine [-1,1] (fires above 0), lateral
//! engine [-1,1] (|a|>0.5 fires left/right).

use anyhow::{ensure, Result};

use crate::util::json::{hex_f64s, parse_hex_f64s, Json};
use crate::util::Rng;

use super::{Action, Env, Transition};

const DT: f64 = 1.0 / 50.0;
const GRAVITY: f64 = -1.625; // lunar g, scaled like the Gym env
const MAIN_POWER: f64 = 6.0;
const SIDE_POWER: f64 = 0.6;
const ANGULAR_DAMP: f64 = 0.3;
const LEG_HEIGHT: f64 = 0.1;

#[derive(Clone, Debug, Default)]
pub struct LunarLanderCont {
    x: f64,
    y: f64,
    vx: f64,
    vy: f64,
    theta: f64,
    omega: f64,
    left_contact: bool,
    right_contact: bool,
    steps: usize,
    prev_shaping: Option<f64>,
}

impl LunarLanderCont {
    pub fn new() -> Self {
        Self::default()
    }

    fn obs(&self) -> Vec<f32> {
        vec![
            self.x as f32,
            self.y as f32,
            self.vx as f32,
            self.vy as f32,
            self.theta as f32,
            self.omega as f32,
            self.left_contact as u8 as f32,
            self.right_contact as u8 as f32,
        ]
    }

    /// Gym-style potential shaping: closer + slower + upright is better.
    fn shaping(&self) -> f64 {
        -100.0 * (self.x * self.x + self.y * self.y).sqrt()
            - 100.0 * (self.vx * self.vx + self.vy * self.vy).sqrt()
            - 100.0 * self.theta.abs()
            + 10.0 * self.left_contact as u8 as f64
            + 10.0 * self.right_contact as u8 as f64
    }
}

impl Env for LunarLanderCont {
    fn obs_dim(&self) -> usize {
        8
    }

    fn action_dim(&self) -> usize {
        2
    }

    fn is_discrete(&self) -> bool {
        false
    }

    fn max_steps(&self) -> usize {
        1000
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        *self = LunarLanderCont {
            x: rng.uniform_in(-0.3, 0.3),
            y: 1.4,
            vx: rng.uniform_in(-0.2, 0.2),
            vy: rng.uniform_in(-0.1, 0.0),
            theta: rng.uniform_in(-0.1, 0.1),
            omega: rng.uniform_in(-0.05, 0.05),
            ..Default::default()
        };
        self.obs()
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Transition {
        let a = action.continuous();
        let main = (a[0] as f64).clamp(-1.0, 1.0);
        let side = (a[1] as f64).clamp(-1.0, 1.0);
        // Main engine: fires when commanded > 0, thrust along body axis.
        let main_thrust = if main > 0.0 { MAIN_POWER * (0.5 + 0.5 * main) } else { 0.0 };
        // Side engines: fire when |side| > 0.5, torque + lateral force.
        let side_thrust = if side.abs() > 0.5 { SIDE_POWER * side.signum() * (side.abs() * 2.0 - 1.0).min(1.0) } else { 0.0 };
        let (sin_t, cos_t) = self.theta.sin_cos();
        let ax = -main_thrust * sin_t + side_thrust * cos_t;
        let ay = main_thrust * cos_t + side_thrust * sin_t + GRAVITY;
        self.vx += ax * DT;
        self.vy += ay * DT;
        self.x += self.vx * DT;
        self.y += self.vy * DT;
        self.omega += (-side_thrust * 2.0 - ANGULAR_DAMP * self.omega) * DT;
        self.theta += self.omega * DT;
        self.steps += 1;

        self.left_contact = self.y <= LEG_HEIGHT && self.theta < 0.2;
        self.right_contact = self.y <= LEG_HEIGHT && self.theta > -0.2;

        let mut reward = 0.0;
        let shaping = self.shaping();
        if let Some(prev) = self.prev_shaping {
            reward += shaping - prev;
        }
        self.prev_shaping = Some(shaping);
        // fuel costs (Gym constants)
        reward -= 0.30 * (main_thrust / MAIN_POWER);
        reward -= 0.03 * (side_thrust.abs() / SIDE_POWER);

        let mut done = false;
        // Touchdown / crash.
        if self.y <= 0.0 {
            done = true;
            let soft = self.vy.abs() < 0.5 && self.theta.abs() < 0.3 && self.x.abs() < 0.5;
            reward += if soft { 100.0 } else { -100.0 };
        }
        // Flying out of bounds is a crash.
        if self.x.abs() > 1.5 || self.y > 2.0 {
            done = true;
            reward -= 100.0;
        }
        if self.steps >= self.max_steps() {
            done = true;
        }
        Transition { obs: self.obs(), reward, done }
    }

    fn save_state(&self) -> Json {
        let phase = [self.x, self.y, self.vx, self.vy, self.theta, self.omega];
        Json::obj(vec![
            ("phase", Json::Str(hex_f64s(&phase))),
            ("left_contact", Json::Bool(self.left_contact)),
            ("right_contact", Json::Bool(self.right_contact)),
            ("steps", Json::Num(self.steps as f64)),
            (
                "prev_shaping",
                match self.prev_shaping {
                    Some(s) => Json::Str(hex_f64s(&[s])),
                    None => Json::Null,
                },
            ),
        ])
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        let p = parse_hex_f64s(state.req_str("phase")?)?;
        ensure!(p.len() == 6, "lander state: expected 6 phase values, got {}", p.len());
        self.x = p[0];
        self.y = p[1];
        self.vx = p[2];
        self.vy = p[3];
        self.theta = p[4];
        self.omega = p[5];
        self.left_contact = state
            .req("left_contact")?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("lander state: bad left_contact"))?;
        self.right_contact = state
            .req("right_contact")?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("lander state: bad right_contact"))?;
        self.steps = state.req_u64("steps")? as usize;
        self.prev_shaping = match state.req("prev_shaping")? {
            Json::Null => None,
            other => {
                let s = other
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("lander state: bad prev_shaping"))?;
                let v = parse_hex_f64s(s)?;
                ensure!(v.len() == 1, "lander state: bad prev_shaping length");
                Some(v[0])
            }
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::contract_check;

    #[test]
    fn contract() {
        contract_check(&mut LunarLanderCont::new(), 31);
    }

    #[test]
    fn free_fall_crashes_with_penalty() {
        let mut env = LunarLanderCont::new();
        let mut rng = Rng::new(9);
        env.reset(&mut rng);
        let mut total = 0.0;
        loop {
            let t = env.step(&Action::Continuous(vec![-1.0, 0.0]), &mut rng);
            total += t.reward;
            if t.done {
                break;
            }
        }
        assert!(total < 0.0, "free fall should score badly, got {total}");
    }

    #[test]
    fn hover_controller_lands_softly_sometimes() {
        // Simple PD on vertical speed + attitude: should land (y<=0) with
        // low speed reasonably often -> mean reward far above free fall.
        let mut env = LunarLanderCont::new();
        let mut rng = Rng::new(10);
        let mut totals = Vec::new();
        for _ in 0..10 {
            let mut obs = env.reset(&mut rng);
            let mut total = 0.0;
            loop {
                let target_vy = -0.25f32;
                let main = ((target_vy - obs[3]) * 2.0 - 0.3 * obs[1].min(0.4)) as f64;
                let side = (-obs[4] * 2.0 - obs[5]) as f64;
                let t = env.step(
                    &Action::Continuous(vec![main as f32, side as f32]),
                    &mut rng,
                );
                obs = t.obs;
                total += t.reward;
                if t.done {
                    break;
                }
            }
            totals.push(total);
        }
        let mean = crate::util::stats::mean(&totals);
        assert!(mean > -50.0, "PD hover too weak: mean {mean}");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = LunarLanderCont::new();
            let mut rng = Rng::new(seed);
            env.reset(&mut rng);
            let mut v = Vec::new();
            for _ in 0..50 {
                v.extend(env.step(&Action::Continuous(vec![0.5, 0.1]), &mut rng).obs);
            }
            v
        };
        assert_eq!(run(1), run(1));
    }
}
