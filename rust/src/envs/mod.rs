//! DRL environments (paper §V-A), reimplemented in rust from the Gym /
//! control-theory dynamics so the whole request path is Python-free:
//!
//! * CartPole (discrete) — classic control;
//! * InvertedPendulum (continuous) — the MuJoCo task's planar dynamics;
//! * MountainCarContinuous — energy-accumulation task;
//! * LunarLanderContinuous — simplified 2-D rigid-body lander;
//! * mini-Breakout / mini-MsPacman — synthetic pixel environments
//!   standing in for ALE (DESIGN.md §Substitutions), rendering
//!   12×12×4 (convergence runs) or 84×84×4 (timing shapes) frames.

pub mod atari_sim;
pub mod batched;
pub mod cartpole;
pub mod lunar_lander;
pub mod mountain_car;
pub mod pendulum;

pub use atari_sim::{MiniBreakout, MiniMsPacman};
pub use batched::{lane_rngs, BatchedEnv};
pub use cartpole::CartPole;
pub use lunar_lander::LunarLanderCont;
pub use mountain_car::MountainCarCont;
pub use pendulum::InvertedPendulum;

use anyhow::{anyhow, Result};

use crate::util::json::Json;
use crate::util::Rng;

/// Action passed to an environment step.
#[derive(Clone, Debug)]
pub enum Action {
    Discrete(usize),
    Continuous(Vec<f32>),
}

impl Action {
    /// Variant name, for mis-wire diagnostics.
    pub fn kind(&self) -> &'static str {
        match self {
            Action::Discrete(_) => "discrete",
            Action::Continuous(_) => "continuous",
        }
    }

    /// Checked accessor: the discrete action index, or a clear error when
    /// a continuous-policy agent was wired to a discrete-action env.
    pub fn try_discrete(&self) -> Result<usize> {
        match self {
            Action::Discrete(a) => Ok(*a),
            Action::Continuous(_) => Err(anyhow!(
                "expected a discrete action, got a continuous one (mis-wired env/agent combo?)"
            )),
        }
    }

    /// Checked accessor: the continuous action vector, or a clear error.
    pub fn try_continuous(&self) -> Result<&[f32]> {
        match self {
            Action::Continuous(a) => Ok(a),
            Action::Discrete(_) => Err(anyhow!(
                "expected a continuous action, got a discrete one (mis-wired env/agent combo?)"
            )),
        }
    }

    pub fn discrete(&self) -> usize {
        self.try_discrete().unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn continuous(&self) -> &[f32] {
        self.try_continuous().unwrap_or_else(|e| panic!("{e}"))
    }
}

/// Step outcome.
#[derive(Clone, Debug)]
pub struct Transition {
    pub obs: Vec<f32>,
    pub reward: f64,
    pub done: bool,
}

/// Common environment interface (PS-side in the paper's mapping: the
/// Environment Step stage runs on the CPU, Fig 1).
///
/// `Send` is a supertrait so [`BatchedEnv`] can step lanes on the
/// `exec::pool` workers; every env here is plain data.
pub trait Env: Send {
    /// Observation dimension (flattened).
    fn obs_dim(&self) -> usize;
    /// Discrete action count, or continuous action dimension.
    fn action_dim(&self) -> usize;
    fn is_discrete(&self) -> bool;
    /// Reset with fresh randomness; returns the initial observation.
    fn reset(&mut self, rng: &mut Rng) -> Vec<f32>;
    /// Advance one step.
    fn step(&mut self, action: &Action, rng: &mut Rng) -> Transition;
    /// Episode step limit (truncation).
    fn max_steps(&self) -> usize;
    /// Bit-exact snapshot of the env's full internal state, for
    /// checkpointing mid-episode (f64 dynamics are hex-encoded so
    /// chaotic systems resume on the identical trajectory).
    fn save_state(&self) -> Json;
    /// Restore a [`Env::save_state`] snapshot into an
    /// identically-configured env.
    fn restore_state(&mut self, state: &Json) -> Result<()>;
}

/// Pack a bool grid (bricks, pellets, contact flags…) as a '0'/'1'
/// string — compact and trivially bit-exact.
pub(crate) fn bools_to_bits(v: &[bool]) -> String {
    v.iter().map(|&b| if b { '1' } else { '0' }).collect()
}

/// Inverse of [`bools_to_bits`]; errors on any character outside {0,1}.
pub(crate) fn bits_to_bools(s: &str) -> Result<Vec<bool>> {
    s.chars()
        .map(|c| match c {
            '0' => Ok(false),
            '1' => Ok(true),
            _ => Err(anyhow!("bad bit character {c:?} in env state")),
        })
        .collect()
}

/// Shared test helper: roll an env for a full episode with random actions
/// and sanity-check the contract.
#[cfg(test)]
pub(crate) fn contract_check(env: &mut dyn Env, seed: u64) {
    let mut rng = Rng::new(seed);
    let obs = env.reset(&mut rng);
    assert_eq!(obs.len(), env.obs_dim());
    assert!(obs.iter().all(|x| x.is_finite()));
    let mut steps = 0;
    loop {
        let action = if env.is_discrete() {
            Action::Discrete(rng.below(env.action_dim()))
        } else {
            Action::Continuous(
                (0..env.action_dim()).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            )
        };
        let t = env.step(&action, &mut rng);
        assert_eq!(t.obs.len(), env.obs_dim());
        assert!(t.obs.iter().all(|x| x.is_finite()), "non-finite obs at step {steps}");
        assert!(t.reward.is_finite());
        steps += 1;
        if t.done || steps >= env.max_steps() + 10 {
            break;
        }
    }
    assert!(steps <= env.max_steps() + 1, "episode never terminated/truncated");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_action(env: &dyn Env, rng: &mut Rng) -> Action {
        if env.is_discrete() {
            Action::Discrete(rng.below(env.action_dim()))
        } else {
            Action::Continuous(
                (0..env.action_dim()).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            )
        }
    }

    /// Roll to mid-episode, snapshot env + rng, restore into a fresh env,
    /// and assert both resume on the bit-identical trajectory (obs bits,
    /// reward bits, done flags) — including RNG-consuming steps/resets.
    fn state_check(mut make: impl FnMut() -> Box<dyn Env>, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut env = make();
        env.reset(&mut rng);
        for _ in 0..7 {
            let a = rand_action(env.as_ref(), &mut rng);
            if env.step(&a, &mut rng).done {
                env.reset(&mut rng);
            }
        }
        let snap = env.save_state();
        let (st, spare) = rng.state_parts();
        let mut env2 = make();
        env2.restore_state(&snap).unwrap();
        let mut rng2 = Rng::from_parts(st, spare);
        for step in 0..11 {
            let a1 = rand_action(env.as_ref(), &mut rng);
            let a2 = rand_action(env2.as_ref(), &mut rng2);
            let t1 = env.step(&a1, &mut rng);
            let t2 = env2.step(&a2, &mut rng2);
            let bits = |o: &[f32]| o.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&t1.obs), bits(&t2.obs), "obs diverged at step {step}");
            assert_eq!(t1.reward.to_bits(), t2.reward.to_bits(), "reward diverged");
            assert_eq!(t1.done, t2.done, "done diverged at step {step}");
            if t1.done {
                env.reset(&mut rng);
                env2.reset(&mut rng2);
            }
        }
    }

    #[test]
    fn save_restore_resumes_identically_for_all_envs() {
        state_check(|| Box::new(CartPole::new()) as Box<dyn Env>, 11);
        state_check(|| Box::new(InvertedPendulum::new()) as Box<dyn Env>, 12);
        state_check(|| Box::new(MountainCarCont::new()) as Box<dyn Env>, 13);
        state_check(|| Box::new(LunarLanderCont::new()) as Box<dyn Env>, 14);
        state_check(|| Box::new(MiniBreakout::mini()) as Box<dyn Env>, 15);
        state_check(|| Box::new(MiniMsPacman::mini()) as Box<dyn Env>, 16);
    }

    #[test]
    fn bit_strings_round_trip_and_reject_junk() {
        let v = vec![true, false, false, true, true];
        assert_eq!(bools_to_bits(&v), "10011");
        assert_eq!(bits_to_bools("10011").unwrap(), v);
        assert!(bits_to_bools("10x1").is_err());
        assert!(bits_to_bools("").unwrap().is_empty());
    }
}
