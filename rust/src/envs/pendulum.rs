//! InvertedPendulum (MuJoCo task, planar dynamics): keep a pole upright
//! on a cart with continuous force control.  State (x, ẋ, θ, θ̇); reward
//! +1 per step alive; terminates when |θ| > 0.2 rad (MuJoCo's threshold).

use anyhow::{ensure, Result};

use crate::util::json::{hex_f64s, parse_hex_f64s, Json};
use crate::util::Rng;

use super::{Action, Env, Transition};

const DT: f64 = 0.02;
const GRAVITY: f64 = 9.81;
const MASS_CART: f64 = 1.0;
const MASS_POLE: f64 = 0.3;
const LENGTH: f64 = 0.6; // pole half-length
const FORCE_SCALE: f64 = 15.0;
const THETA_LIMIT: f64 = 0.2;
const X_LIMIT: f64 = 1.0;

#[derive(Clone, Debug, Default)]
pub struct InvertedPendulum {
    x: f64,
    x_dot: f64,
    theta: f64,
    theta_dot: f64,
    steps: usize,
}

impl InvertedPendulum {
    pub fn new() -> Self {
        Self::default()
    }

    fn obs(&self) -> Vec<f32> {
        vec![self.x as f32, self.x_dot as f32, self.theta as f32, self.theta_dot as f32]
    }
}

impl Env for InvertedPendulum {
    fn obs_dim(&self) -> usize {
        4
    }

    fn action_dim(&self) -> usize {
        1
    }

    fn is_discrete(&self) -> bool {
        false
    }

    fn max_steps(&self) -> usize {
        1000
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.x = rng.uniform_in(-0.01, 0.01);
        self.x_dot = rng.uniform_in(-0.01, 0.01);
        self.theta = rng.uniform_in(-0.01, 0.01);
        self.theta_dot = rng.uniform_in(-0.01, 0.01);
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Transition {
        let u = (action.continuous()[0] as f64).clamp(-1.0, 1.0) * FORCE_SCALE;
        let total = MASS_CART + MASS_POLE;
        let (sin_t, cos_t) = self.theta.sin_cos();
        let temp = (u + MASS_POLE * LENGTH * self.theta_dot * self.theta_dot * sin_t) / total;
        let theta_acc = (GRAVITY * sin_t - cos_t * temp)
            / (LENGTH * (4.0 / 3.0 - MASS_POLE * cos_t * cos_t / total));
        let x_acc = temp - MASS_POLE * LENGTH * theta_acc * cos_t / total;
        // semi-implicit Euler keeps the pole dynamics stable
        self.x_dot += DT * x_acc;
        self.x += DT * self.x_dot;
        self.theta_dot += DT * theta_acc;
        self.theta += DT * self.theta_dot;
        self.steps += 1;
        let failed = self.theta.abs() > THETA_LIMIT || self.x.abs() > X_LIMIT;
        let truncated = self.steps >= self.max_steps();
        Transition { obs: self.obs(), reward: 1.0, done: failed || truncated }
    }

    fn save_state(&self) -> Json {
        Json::obj(vec![
            ("phase", Json::Str(hex_f64s(&[self.x, self.x_dot, self.theta, self.theta_dot]))),
            ("steps", Json::Num(self.steps as f64)),
        ])
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        let p = parse_hex_f64s(state.req_str("phase")?)?;
        ensure!(p.len() == 4, "pendulum state: expected 4 phase values, got {}", p.len());
        self.x = p[0];
        self.x_dot = p[1];
        self.theta = p[2];
        self.theta_dot = p[3];
        self.steps = state.req_u64("steps")? as usize;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::contract_check;

    #[test]
    fn contract() {
        contract_check(&mut InvertedPendulum::new(), 11);
    }

    #[test]
    fn zero_action_falls_eventually() {
        let mut env = InvertedPendulum::new();
        let mut rng = Rng::new(5);
        env.reset(&mut rng);
        let mut n = 0;
        loop {
            let t = env.step(&Action::Continuous(vec![0.0]), &mut rng);
            n += 1;
            if t.done {
                break;
            }
        }
        assert!(n < env.max_steps(), "uncontrolled pole should fall, lasted {n}");
    }

    #[test]
    fn proportional_controller_balances() {
        // u = -k θ - d θ̇ keeps the pole up far longer than zero control.
        let mut env = InvertedPendulum::new();
        let mut rng = Rng::new(6);
        let mut obs = env.reset(&mut rng);
        let mut n = 0;
        loop {
            // push the cart toward the lean (+θ ⇒ +u) to move under the pole
            let u = (8.0 * obs[2] as f64 + 1.5 * obs[3] as f64 + 0.3 * obs[0] as f64
                + 0.5 * obs[1] as f64)
                .clamp(-1.0, 1.0);
            let t = env.step(&Action::Continuous(vec![u as f32]), &mut rng);
            obs = t.obs;
            n += 1;
            if t.done {
                break;
            }
        }
        assert!(n >= 500, "PD controller should balance long, lasted {n}");
    }
}
