//! Synthetic pixel environments standing in for ALE Breakout / MsPacman
//! (DESIGN.md §Substitutions).
//!
//! Both render a stacked 4-frame observation like the Nature-DQN
//! preprocessing: `size`×`size`×4, values in [0,1].  `size = 12` is the
//! convergence-run variant (matching the `*_mini` artifacts); `size = 84`
//! reproduces the full Table III observation shape for timing figures.
//!
//! * **MiniBreakout** — paddle, ball with reflective physics, brick rows;
//!   reward +1 per brick, episode ends on ball loss or board clear.
//! * **MiniMsPacman** — pellet field + one chasing ghost on a torus grid;
//!   reward +1 per pellet, -100 on capture, 9 actions (8 directions +
//!   stay) like MsPacman's |A| = 9.

use anyhow::{ensure, Result};

use crate::util::json::{hex_f32s, hex_f64s, parse_hex_f32s, parse_hex_f64s, Json};
use crate::util::Rng;

use super::{bits_to_bools, bools_to_bits, Action, Env, Transition};

const FRAMES: usize = 4;

/// Serialize a frame stack as an array of per-frame hex strings.
fn stack_to_json(stack: &[Vec<f32>]) -> Json {
    Json::Arr(stack.iter().map(|f| Json::Str(hex_f32s(f))).collect())
}

/// Restore a frame stack saved by [`stack_to_json`], validating shape.
fn stack_from_json(v: &Json, frame_len: usize) -> Result<Vec<Vec<f32>>> {
    let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("frame stack: expected array"))?;
    ensure!(arr.len() == FRAMES, "frame stack: expected {FRAMES} frames, got {}", arr.len());
    arr.iter()
        .map(|f| {
            let s = f.as_str().ok_or_else(|| anyhow::anyhow!("frame stack: bad frame"))?;
            let frame = parse_hex_f32s(s)?;
            ensure!(frame.len() == frame_len, "frame stack: bad frame length");
            Ok(frame)
        })
        .collect()
}

fn push_frame(stack: &mut Vec<Vec<f32>>, frame: Vec<f32>) {
    stack.remove(0);
    stack.push(frame);
}

fn stacked_obs(stack: &[Vec<f32>]) -> Vec<f32> {
    // channel-last (H, W, C) to match the NHWC artifacts
    let hw = stack[0].len();
    let mut out = vec![0.0f32; hw * FRAMES];
    for (c, frame) in stack.iter().enumerate() {
        for (i, &v) in frame.iter().enumerate() {
            out[i * FRAMES + c] = v;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Mini-Breakout
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct MiniBreakout {
    size: usize,
    paddle: i32,
    ball: (f64, f64),
    vel: (f64, f64),
    bricks: Vec<bool>, // brick_rows × size
    brick_rows: usize,
    stack: Vec<Vec<f32>>,
    steps: usize,
}

impl MiniBreakout {
    pub fn new(size: usize) -> Self {
        let brick_rows = (size / 4).max(1);
        MiniBreakout {
            size,
            paddle: 0,
            ball: (0.0, 0.0),
            vel: (0.0, 0.0),
            bricks: vec![true; brick_rows * size],
            brick_rows,
            stack: vec![vec![0.0; size * size]; FRAMES],
            steps: 0,
        }
    }

    pub fn mini() -> Self {
        Self::new(12)
    }

    /// Full Table III observation shape (84×84×4) for timing figures.
    pub fn full() -> Self {
        Self::new(84)
    }

    fn render(&self) -> Vec<f32> {
        let n = self.size;
        let mut f = vec![0.0f32; n * n];
        for r in 0..self.brick_rows {
            for c in 0..n {
                if self.bricks[r * n + c] {
                    f[r * n + c] = 0.5;
                }
            }
        }
        let bx = (self.ball.0.round() as i32).clamp(0, n as i32 - 1) as usize;
        let by = (self.ball.1.round() as i32).clamp(0, n as i32 - 1) as usize;
        f[by * n + bx] = 1.0;
        let py = n - 1;
        for dx in -1..=1i32 {
            let px = (self.paddle + dx).clamp(0, n as i32 - 1) as usize;
            f[py * n + px] = 0.8;
        }
        f
    }
}

impl Env for MiniBreakout {
    fn obs_dim(&self) -> usize {
        self.size * self.size * FRAMES
    }

    fn action_dim(&self) -> usize {
        4 // noop, left, right, (fire≡noop) — Breakout's |A| = 4
    }

    fn is_discrete(&self) -> bool {
        true
    }

    fn max_steps(&self) -> usize {
        500
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        let n = self.size;
        self.paddle = (n / 2) as i32;
        self.ball = (rng.uniform_in(1.0, n as f64 - 2.0), (self.brick_rows + 1) as f64);
        self.vel = (if rng.uniform() < 0.5 { 0.45 } else { -0.45 }, 0.45);
        self.bricks = vec![true; self.brick_rows * n];
        self.stack = vec![vec![0.0; n * n]; FRAMES];
        self.steps = 0;
        let frame = self.render();
        for _ in 0..FRAMES {
            push_frame(&mut self.stack, frame.clone());
        }
        stacked_obs(&self.stack)
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Transition {
        let n = self.size as f64;
        match action.discrete() {
            1 => self.paddle = (self.paddle - 1).max(1),
            2 => self.paddle = (self.paddle + 1).min(self.size as i32 - 2),
            _ => {}
        }
        let (mut x, mut y) = self.ball;
        let (mut vx, mut vy) = self.vel;
        x += vx;
        y += vy;
        // walls
        if x <= 0.0 || x >= n - 1.0 {
            vx = -vx;
            x = x.clamp(0.0, n - 1.0);
        }
        if y <= 0.0 {
            vy = -vy;
            y = 0.0;
        }
        let mut reward = 0.0;
        // bricks
        let bx = x.round() as usize % self.size;
        let by = y.round() as i32;
        if by >= 0 && (by as usize) < self.brick_rows {
            let idx = by as usize * self.size + bx;
            if self.bricks[idx] {
                self.bricks[idx] = false;
                reward += 1.0;
                vy = -vy;
            }
        }
        // paddle
        let mut lost = false;
        if y >= n - 2.0 && vy > 0.0 {
            if (x - self.paddle as f64).abs() <= 1.5 {
                vy = -vy;
                // english: hit offset steers the ball
                vx += 0.15 * (x - self.paddle as f64);
                vx = vx.clamp(-0.8, 0.8);
                y = n - 2.0;
            } else if y >= n - 1.0 {
                lost = true;
            }
        }
        self.ball = (x, y);
        self.vel = (vx, vy);
        self.steps += 1;
        let cleared = self.bricks.iter().all(|&b| !b);
        if cleared {
            reward += 10.0;
        }
        let frame = self.render();
        push_frame(&mut self.stack, frame);
        let done = lost || cleared || self.steps >= self.max_steps();
        Transition { obs: stacked_obs(&self.stack), reward, done }
    }

    fn save_state(&self) -> Json {
        Json::obj(vec![
            ("size", Json::Num(self.size as f64)),
            ("paddle", Json::Num(f64::from(self.paddle))),
            ("ball", Json::Str(hex_f64s(&[self.ball.0, self.ball.1, self.vel.0, self.vel.1]))),
            ("bricks", Json::Str(bools_to_bits(&self.bricks))),
            ("stack", stack_to_json(&self.stack)),
            ("steps", Json::Num(self.steps as f64)),
        ])
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        ensure!(
            state.req_u64("size")? as usize == self.size,
            "breakout state: board size mismatch"
        );
        let b = parse_hex_f64s(state.req_str("ball")?)?;
        ensure!(b.len() == 4, "breakout state: expected 4 ball values, got {}", b.len());
        let bricks = bits_to_bools(state.req_str("bricks")?)?;
        ensure!(bricks.len() == self.brick_rows * self.size, "breakout state: brick count");
        self.paddle = state.req_u64("paddle")? as i32;
        self.ball = (b[0], b[1]);
        self.vel = (b[2], b[3]);
        self.bricks = bricks;
        self.stack = stack_from_json(state.req("stack")?, self.size * self.size)?;
        self.steps = state.req_u64("steps")? as usize;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Mini-MsPacman
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct MiniMsPacman {
    size: usize,
    player: (i32, i32),
    ghost: (i32, i32),
    pellets: Vec<bool>,
    stack: Vec<Vec<f32>>,
    steps: usize,
}

/// 8 directions + stay = 9 actions (MsPacman's |A|).
const DIRS: [(i32, i32); 9] =
    [(0, 0), (0, -1), (0, 1), (-1, 0), (1, 0), (-1, -1), (1, -1), (-1, 1), (1, 1)];

impl MiniMsPacman {
    pub fn new(size: usize) -> Self {
        MiniMsPacman {
            size,
            player: (0, 0),
            ghost: (0, 0),
            pellets: vec![true; size * size],
            stack: vec![vec![0.0; size * size]; FRAMES],
            steps: 0,
        }
    }

    pub fn mini() -> Self {
        Self::new(12)
    }

    pub fn full() -> Self {
        Self::new(84)
    }

    fn render(&self) -> Vec<f32> {
        let n = self.size;
        let mut f = vec![0.0f32; n * n];
        for (i, &p) in self.pellets.iter().enumerate() {
            if p {
                f[i] = 0.3;
            }
        }
        f[self.ghost.1 as usize * n + self.ghost.0 as usize] = 0.7;
        f[self.player.1 as usize * n + self.player.0 as usize] = 1.0;
        f
    }

    fn wrap(&self, v: i32) -> i32 {
        (v + self.size as i32) % self.size as i32
    }
}

impl Env for MiniMsPacman {
    fn obs_dim(&self) -> usize {
        self.size * self.size * FRAMES
    }

    fn action_dim(&self) -> usize {
        9
    }

    fn is_discrete(&self) -> bool {
        true
    }

    fn max_steps(&self) -> usize {
        400
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        let n = self.size as i32;
        self.player = (rng.below(self.size) as i32, rng.below(self.size) as i32);
        self.ghost = (self.wrap(self.player.0 + n / 2), self.wrap(self.player.1 + n / 2));
        self.pellets = vec![true; self.size * self.size];
        self.pellets[self.player.1 as usize * self.size + self.player.0 as usize] = false;
        self.stack = vec![vec![0.0; self.size * self.size]; FRAMES];
        self.steps = 0;
        let frame = self.render();
        for _ in 0..FRAMES {
            push_frame(&mut self.stack, frame.clone());
        }
        stacked_obs(&self.stack)
    }

    fn step(&mut self, action: &Action, rng: &mut Rng) -> Transition {
        let (dx, dy) = DIRS[action.discrete().min(8)];
        self.player = (self.wrap(self.player.0 + dx), self.wrap(self.player.1 + dy));
        let mut reward = 0.0;
        let idx = self.player.1 as usize * self.size + self.player.0 as usize;
        if self.pellets[idx] {
            self.pellets[idx] = false;
            reward += 1.0;
        }
        // Ghost: biased pursuit (75 % greedy step, 25 % random).
        let (gx, gy) = self.ghost;
        let step = if rng.uniform() < 0.75 {
            let ddx = (self.player.0 - gx).signum();
            let ddy = (self.player.1 - gy).signum();
            (ddx, ddy)
        } else {
            DIRS[1 + rng.below(8)]
        };
        self.ghost = (self.wrap(gx + step.0), self.wrap(gy + step.1));
        self.steps += 1;
        let caught = self.ghost == self.player;
        if caught {
            reward -= 100.0;
        }
        let cleared = self.pellets.iter().all(|&p| !p);
        if cleared {
            reward += 50.0;
        }
        let frame = self.render();
        push_frame(&mut self.stack, frame);
        let done = caught || cleared || self.steps >= self.max_steps();
        Transition { obs: stacked_obs(&self.stack), reward, done }
    }

    fn save_state(&self) -> Json {
        Json::obj(vec![
            ("size", Json::Num(self.size as f64)),
            ("player_x", Json::Num(f64::from(self.player.0))),
            ("player_y", Json::Num(f64::from(self.player.1))),
            ("ghost_x", Json::Num(f64::from(self.ghost.0))),
            ("ghost_y", Json::Num(f64::from(self.ghost.1))),
            ("pellets", Json::Str(bools_to_bits(&self.pellets))),
            ("stack", stack_to_json(&self.stack)),
            ("steps", Json::Num(self.steps as f64)),
        ])
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        ensure!(
            state.req_u64("size")? as usize == self.size,
            "pacman state: board size mismatch"
        );
        let pellets = bits_to_bools(state.req_str("pellets")?)?;
        ensure!(pellets.len() == self.size * self.size, "pacman state: pellet count");
        self.player = (state.req_u64("player_x")? as i32, state.req_u64("player_y")? as i32);
        self.ghost = (state.req_u64("ghost_x")? as i32, state.req_u64("ghost_y")? as i32);
        self.pellets = pellets;
        self.stack = stack_from_json(state.req("stack")?, self.size * self.size)?;
        self.steps = state.req_u64("steps")? as usize;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::contract_check;

    #[test]
    fn breakout_contract() {
        contract_check(&mut MiniBreakout::mini(), 1);
    }

    #[test]
    fn pacman_contract() {
        contract_check(&mut MiniMsPacman::mini(), 2);
    }

    #[test]
    fn obs_shapes_match_artifacts() {
        let mut b = MiniBreakout::mini();
        let mut rng = Rng::new(3);
        assert_eq!(b.reset(&mut rng).len(), 12 * 12 * 4);
        assert_eq!(b.action_dim(), 4);
        let mut p = MiniMsPacman::mini();
        assert_eq!(p.reset(&mut rng).len(), 12 * 12 * 4);
        assert_eq!(p.action_dim(), 9);
    }

    #[test]
    fn full_shape_matches_table3() {
        let mut b = MiniBreakout::full();
        let mut rng = Rng::new(4);
        assert_eq!(b.reset(&mut rng).len(), 84 * 84 * 4);
    }

    #[test]
    fn breakout_tracking_paddle_scores() {
        // Follow the ball: should hit bricks and outscore doing nothing.
        let mut env = MiniBreakout::mini();
        let mut rng = Rng::new(5);
        let mut track_total = 0.0;
        for _ in 0..5 {
            env.reset(&mut rng);
            loop {
                let a = if env.ball.0 < env.paddle as f64 - 0.2 {
                    1
                } else if env.ball.0 > env.paddle as f64 + 0.2 {
                    2
                } else {
                    0
                };
                let t = env.step(&Action::Discrete(a), &mut rng);
                track_total += t.reward;
                if t.done {
                    break;
                }
            }
        }
        let mut idle_total = 0.0;
        for _ in 0..5 {
            env.reset(&mut rng);
            loop {
                let t = env.step(&Action::Discrete(0), &mut rng);
                idle_total += t.reward;
                if t.done {
                    break;
                }
            }
        }
        assert!(
            track_total > idle_total,
            "tracking {track_total} should beat idle {idle_total}"
        );
        assert!(track_total >= 5.0, "tracking should break bricks: {track_total}");
    }

    #[test]
    fn pacman_pellets_monotone_and_ghost_catches_idler() {
        let mut env = MiniMsPacman::mini();
        let mut rng = Rng::new(6);
        env.reset(&mut rng);
        let before = env.pellets.iter().filter(|&&p| p).count();
        let mut caught = false;
        for _ in 0..400 {
            let t = env.step(&Action::Discrete(0), &mut rng);
            if t.done {
                caught = t.reward < -50.0;
                break;
            }
        }
        let after = env.pellets.iter().filter(|&&p| p).count();
        assert!(after <= before);
        assert!(caught, "pursuing ghost should catch a stationary player");
    }

    #[test]
    fn frame_stack_shifts() {
        let mut env = MiniBreakout::mini();
        let mut rng = Rng::new(7);
        let o1 = env.reset(&mut rng);
        let o2 = env.step(&Action::Discrete(2), &mut rng).obs;
        assert_eq!(o1.len(), o2.len());
        assert_ne!(o1, o2);
    }
}
