//! On-policy rollout buffer with GAE(λ) (A2C / PPO).
//!
//! Advantage estimation is coordinator work in AP-DRL's mapping (the
//! paper cites HEPPO's hardware GAE as related work; here it is cheap
//! L3 arithmetic between artifact invocations).

/// One on-policy step record.
#[derive(Clone, Debug)]
pub struct RolloutStep {
    pub obs: Vec<f32>,
    /// Discrete index or continuous vector (one of the two used).
    pub action_i: i32,
    pub action_c: Vec<f32>,
    pub logp: f32,
    pub value: f32,
    pub reward: f32,
    pub done: bool,
}

/// Fixed-horizon rollout storage + GAE computation.
pub struct RolloutBuffer {
    pub steps: Vec<RolloutStep>,
    horizon: usize,
    gamma: f64,
    lambda: f64,
}

/// Flat on-policy batch (artifact-ready).
pub struct RolloutBatch {
    pub obs: Vec<f32>,
    pub actions_i32: Vec<i32>,
    pub actions_f32: Vec<f32>,
    pub logp_old: Vec<f32>,
    pub returns: Vec<f32>,
    pub advantages: Vec<f32>,
    pub size: usize,
}

impl RolloutBuffer {
    pub fn new(horizon: usize, gamma: f64, lambda: f64) -> Self {
        RolloutBuffer { steps: Vec::with_capacity(horizon), horizon, gamma, lambda }
    }

    pub fn push(&mut self, step: RolloutStep) {
        self.steps.push(step);
    }

    pub fn full(&self) -> bool {
        self.steps.len() >= self.horizon
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Compute GAE advantages + returns and drain the buffer.
    /// `last_value` bootstraps the value of the state after the final
    /// step (0 if that step terminated).
    pub fn finish(&mut self, last_value: f32, normalize_adv: bool) -> RolloutBatch {
        let n = self.steps.len();
        let mut adv = vec![0.0f32; n];
        let mut gae = 0.0f64;
        let mut next_value = last_value as f64;
        for t in (0..n).rev() {
            let s = &self.steps[t];
            let nonterminal = if s.done { 0.0 } else { 1.0 };
            let delta = s.reward as f64 + self.gamma * next_value * nonterminal - s.value as f64;
            gae = delta + self.gamma * self.lambda * nonterminal * gae;
            adv[t] = gae as f32;
            next_value = s.value as f64;
        }
        let returns: Vec<f32> =
            adv.iter().zip(&self.steps).map(|(a, s)| a + s.value).collect();
        let mut advantages = adv;
        if normalize_adv && n > 1 {
            let xs: Vec<f64> = advantages.iter().map(|&x| x as f64).collect();
            let m = crate::util::stats::mean(&xs);
            let s = crate::util::stats::std_dev(&xs).max(1e-8);
            for a in advantages.iter_mut() {
                *a = ((*a as f64 - m) / s) as f32;
            }
        }
        let mut batch = RolloutBatch {
            obs: Vec::with_capacity(n * self.steps[0].obs.len()),
            actions_i32: Vec::with_capacity(n),
            actions_f32: Vec::new(),
            logp_old: Vec::with_capacity(n),
            returns,
            advantages,
            size: n,
        };
        for s in &self.steps {
            batch.obs.extend_from_slice(&s.obs);
            batch.actions_i32.push(s.action_i);
            batch.actions_f32.extend_from_slice(&s.action_c);
            batch.logp_old.push(s.logp);
        }
        self.steps.clear();
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(reward: f32, value: f32, done: bool) -> RolloutStep {
        RolloutStep {
            obs: vec![0.0],
            action_i: 0,
            action_c: vec![],
            logp: 0.0,
            value,
            reward,
            done,
        }
    }

    #[test]
    fn gae_matches_hand_computation() {
        // γ=0.5, λ=0.5, two steps, bootstrap 1.0
        let mut rb = RolloutBuffer::new(2, 0.5, 0.5);
        rb.push(step(1.0, 0.5, false));
        rb.push(step(2.0, 0.25, false));
        let b = rb.finish(1.0, false);
        // δ1 = 2 + 0.5·1 − 0.25 = 2.25 ; A1 = 2.25
        // δ0 = 1 + 0.5·0.25 − 0.5 = 0.625 ; A0 = 0.625 + 0.25·2.25 = 1.1875
        assert!((b.advantages[1] - 2.25).abs() < 1e-6);
        assert!((b.advantages[0] - 1.1875).abs() < 1e-6);
        assert!((b.returns[0] - (1.1875 + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn terminal_cuts_bootstrap() {
        let mut rb = RolloutBuffer::new(2, 0.99, 0.95);
        rb.push(step(1.0, 0.7, true));
        rb.push(step(1.0, 0.3, false));
        let b = rb.finish(5.0, false);
        // step0 terminal: A0 = r - v = 0.3, no leakage from step1/bootstrap
        assert!((b.advantages[0] - 0.3).abs() < 1e-6);
    }

    #[test]
    fn normalization_zero_mean_unit_std() {
        let mut rb = RolloutBuffer::new(8, 0.99, 0.95);
        for k in 0..8 {
            rb.push(step(k as f32, 0.0, false));
        }
        let b = rb.finish(0.0, true);
        let xs: Vec<f64> = b.advantages.iter().map(|&x| x as f64).collect();
        assert!(crate::util::stats::mean(&xs).abs() < 1e-5);
        assert!((crate::util::stats::std_dev(&xs) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn drains_after_finish() {
        let mut rb = RolloutBuffer::new(2, 0.9, 0.9);
        rb.push(step(0.0, 0.0, false));
        rb.push(step(0.0, 0.0, false));
        assert!(rb.full());
        rb.finish(0.0, false);
        assert!(rb.is_empty());
    }
}
