//! On-policy rollout buffer with GAE(λ) (A2C / PPO).
//!
//! Advantage estimation is coordinator work in AP-DRL's mapping (the
//! paper cites HEPPO's hardware GAE as related work; here it is cheap
//! L3 arithmetic between artifact invocations).
//!
//! The buffer is lane-aware for the batched rollout path: with
//! [`RolloutBuffer::ensure_lanes`]`(n)`, pushes interleave `n` actor
//! lanes round-major/lane-minor (storage index `t * lanes + l`) and
//! GAE runs a per-lane strided backward recursion.  At `lanes == 1`
//! the stride is 1, so the arithmetic (and hence every bit of the
//! output) is identical to the scalar recursion it replaced.

use crate::util::json::{hex_f32s, hex_f64s, parse_hex_f32s, parse_hex_f64s, Json, JsonError};

/// One on-policy step record.
#[derive(Clone, Debug)]
pub struct RolloutStep {
    pub obs: Vec<f32>,
    /// Discrete index or continuous vector (one of the two used).
    pub action_i: i32,
    pub action_c: Vec<f32>,
    pub logp: f32,
    pub value: f32,
    pub reward: f32,
    pub done: bool,
}

/// Fixed-horizon rollout storage + GAE computation.
pub struct RolloutBuffer {
    pub steps: Vec<RolloutStep>,
    horizon: usize,
    lanes: usize,
    gamma: f64,
    lambda: f64,
}

/// Flat on-policy batch (artifact-ready).
#[derive(Default)]
pub struct RolloutBatch {
    pub obs: Vec<f32>,
    pub actions_i32: Vec<i32>,
    pub actions_f32: Vec<f32>,
    pub logp_old: Vec<f32>,
    pub returns: Vec<f32>,
    pub advantages: Vec<f32>,
    pub size: usize,
}

impl RolloutBuffer {
    pub fn new(horizon: usize, gamma: f64, lambda: f64) -> Self {
        RolloutBuffer { steps: Vec::with_capacity(horizon), horizon, lanes: 1, gamma, lambda }
    }

    /// Declare the actor-lane count (default 1).  Pushes must then
    /// interleave lanes round-major (`t * lanes + l`), which is what an
    /// agent observing a `BatchedEnv` round does naturally.  Only legal
    /// on an empty buffer — lanes cannot change mid-rollout.
    pub fn ensure_lanes(&mut self, lanes: usize) {
        assert!(lanes >= 1, "lane count must be >= 1");
        if self.lanes != lanes {
            assert!(self.is_empty(), "cannot change lane count mid-rollout");
            self.lanes = lanes;
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn push(&mut self, step: RolloutStep) {
        self.steps.push(step);
    }

    /// A full rollout holds `horizon` rounds of all lanes.
    pub fn full(&self) -> bool {
        self.steps.len() >= self.horizon * self.lanes
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Serialize the partially-filled rollout bit-exactly — a checkpoint
    /// can land mid-horizon, and the restored buffer must finish the
    /// rollout with identical GAE output.
    pub fn to_json(&self) -> Json {
        let step_json = |s: &RolloutStep| {
            Json::obj(vec![
                ("obs", Json::Str(hex_f32s(&s.obs))),
                ("action_i", Json::Num(f64::from(s.action_i))),
                ("action_c", Json::Str(hex_f32s(&s.action_c))),
                ("lvr", Json::Str(hex_f32s(&[s.logp, s.value, s.reward]))),
                ("done", Json::Bool(s.done)),
            ])
        };
        Json::obj(vec![
            ("horizon", Json::Num(self.horizon as f64)),
            ("lanes", Json::Num(self.lanes as f64)),
            ("gl", Json::Str(hex_f64s(&[self.gamma, self.lambda]))),
            ("steps", Json::Arr(self.steps.iter().map(step_json).collect())),
        ])
    }

    /// Rebuild a buffer from a [`RolloutBuffer::to_json`] snapshot.
    pub fn from_json(v: &Json) -> Result<RolloutBuffer, JsonError> {
        let gl = parse_hex_f64s(v.req_str("gl")?)?;
        if gl.len() != 2 {
            return Err(JsonError { msg: "rollout: bad gamma/lambda".into(), pos: 0 });
        }
        let mut rb = RolloutBuffer::new(v.req_u64("horizon")? as usize, gl[0], gl[1]);
        rb.lanes = v.req_u64("lanes")?.max(1) as usize;
        for s in v.req_arr("steps")? {
            let lvr = parse_hex_f32s(s.req_str("lvr")?)?;
            if lvr.len() != 3 {
                return Err(JsonError { msg: "rollout: bad step scalars".into(), pos: 0 });
            }
            rb.steps.push(RolloutStep {
                obs: parse_hex_f32s(s.req_str("obs")?)?,
                action_i: s.req_f64("action_i")? as i32,
                action_c: parse_hex_f32s(s.req_str("action_c")?)?,
                logp: lvr[0],
                value: lvr[1],
                reward: lvr[2],
                done: s.req("done")?.as_bool().unwrap_or(false),
            });
        }
        Ok(rb)
    }

    /// Compute GAE advantages + returns and drain the buffer.
    /// `last_values` bootstraps the value of the state after the final
    /// round, one entry per lane (0 where that lane's step terminated).
    pub fn finish(&mut self, last_values: &[f32], normalize_adv: bool) -> RolloutBatch {
        let mut batch = RolloutBatch::default();
        self.finish_into(last_values, normalize_adv, &mut batch);
        batch
    }

    /// [`finish`](Self::finish) into a caller-owned batch, reusing its
    /// capacity so steady-state training allocates nothing per rollout.
    /// Identical output (asserted in the module tests).
    pub fn finish_into(
        &mut self,
        last_values: &[f32],
        normalize_adv: bool,
        batch: &mut RolloutBatch,
    ) {
        let n = self.steps.len();
        let lanes = self.lanes;
        assert_eq!(last_values.len(), lanes, "one bootstrap value per lane");
        assert_eq!(n % lanes, 0, "rollout length must be whole rounds of all lanes");
        batch.advantages.clear();
        batch.advantages.resize(n, 0.0);
        for (l, &last_value) in last_values.iter().enumerate() {
            let mut gae = 0.0f64;
            let mut next_value = last_value as f64;
            for t in (0..n / lanes).rev() {
                let i = t * lanes + l;
                let s = &self.steps[i];
                let nonterminal = if s.done { 0.0 } else { 1.0 };
                let delta =
                    s.reward as f64 + self.gamma * next_value * nonterminal - s.value as f64;
                gae = delta + self.gamma * self.lambda * nonterminal * gae;
                batch.advantages[i] = gae as f32;
                next_value = s.value as f64;
            }
        }
        batch.returns.clear();
        batch.returns.extend(batch.advantages.iter().zip(&self.steps).map(|(a, s)| a + s.value));
        if normalize_adv && n > 1 {
            let xs: Vec<f64> = batch.advantages.iter().map(|&x| x as f64).collect();
            let m = crate::util::stats::mean(&xs);
            let s = crate::util::stats::std_dev(&xs).max(1e-8);
            for a in batch.advantages.iter_mut() {
                *a = ((*a as f64 - m) / s) as f32;
            }
        }
        batch.obs.clear();
        batch.actions_i32.clear();
        batch.actions_f32.clear();
        batch.logp_old.clear();
        for s in &self.steps {
            batch.obs.extend_from_slice(&s.obs);
            batch.actions_i32.push(s.action_i);
            batch.actions_f32.extend_from_slice(&s.action_c);
            batch.logp_old.push(s.logp);
        }
        batch.size = n;
        self.steps.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(reward: f32, value: f32, done: bool) -> RolloutStep {
        RolloutStep {
            obs: vec![0.0],
            action_i: 0,
            action_c: vec![],
            logp: 0.0,
            value,
            reward,
            done,
        }
    }

    #[test]
    fn gae_matches_hand_computation() {
        // γ=0.5, λ=0.5, two steps, bootstrap 1.0
        let mut rb = RolloutBuffer::new(2, 0.5, 0.5);
        rb.push(step(1.0, 0.5, false));
        rb.push(step(2.0, 0.25, false));
        let b = rb.finish(&[1.0], false);
        // δ1 = 2 + 0.5·1 − 0.25 = 2.25 ; A1 = 2.25
        // δ0 = 1 + 0.5·0.25 − 0.5 = 0.625 ; A0 = 0.625 + 0.25·2.25 = 1.1875
        assert!((b.advantages[1] - 2.25).abs() < 1e-6);
        assert!((b.advantages[0] - 1.1875).abs() < 1e-6);
        assert!((b.returns[0] - (1.1875 + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn terminal_cuts_bootstrap() {
        let mut rb = RolloutBuffer::new(2, 0.99, 0.95);
        rb.push(step(1.0, 0.7, true));
        rb.push(step(1.0, 0.3, false));
        let b = rb.finish(&[5.0], false);
        // step0 terminal: A0 = r - v = 0.3, no leakage from step1/bootstrap
        assert!((b.advantages[0] - 0.3).abs() < 1e-6);
    }

    #[test]
    fn normalization_zero_mean_unit_std() {
        let mut rb = RolloutBuffer::new(8, 0.99, 0.95);
        for k in 0..8 {
            rb.push(step(k as f32, 0.0, false));
        }
        let b = rb.finish(&[0.0], true);
        let xs: Vec<f64> = b.advantages.iter().map(|&x| x as f64).collect();
        assert!(crate::util::stats::mean(&xs).abs() < 1e-5);
        assert!((crate::util::stats::std_dev(&xs) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn drains_after_finish() {
        let mut rb = RolloutBuffer::new(2, 0.9, 0.9);
        rb.push(step(0.0, 0.0, false));
        rb.push(step(0.0, 0.0, false));
        assert!(rb.full());
        rb.finish(&[0.0], false);
        assert!(rb.is_empty());
    }

    #[test]
    fn interleaved_lanes_equal_independent_scalar_buffers() {
        // Two lanes interleaved round-major must produce, per lane, the
        // exact advantages/returns two scalar buffers produce.
        let lane0: [(f32, f32, bool); 3] =
            [(1.0, 0.5, false), (0.5, 0.4, true), (2.0, 0.1, false)];
        let lane1: [(f32, f32, bool); 3] =
            [(0.2, 0.3, false), (0.7, 0.6, false), (1.5, 0.2, false)];
        let boots = [0.8f32, 0.9];

        let mut interleaved = RolloutBuffer::new(3, 0.99, 0.95);
        interleaved.ensure_lanes(2);
        for t in 0..3 {
            for (l, lane) in [lane0, lane1].iter().enumerate() {
                let (r, v, d) = lane[t];
                let mut s = step(r, v, d);
                s.obs = vec![(t * 2 + l) as f32];
                interleaved.push(s);
            }
        }
        assert!(interleaved.full());
        let b = interleaved.finish(&boots, false);

        for (l, lane) in [lane0, lane1].iter().enumerate() {
            let mut scalar = RolloutBuffer::new(3, 0.99, 0.95);
            for &(r, v, d) in lane {
                scalar.push(step(r, v, d));
            }
            let sb = scalar.finish(&[boots[l]], false);
            for t in 0..3 {
                let i = t * 2 + l;
                assert_eq!(b.advantages[i].to_bits(), sb.advantages[t].to_bits());
                assert_eq!(b.returns[i].to_bits(), sb.returns[t].to_bits());
                assert_eq!(b.obs[i], i as f32, "push-order layout");
            }
        }
    }

    #[test]
    fn json_round_trip_mid_horizon_finishes_identically() {
        let mut rb = RolloutBuffer::new(3, 0.99, 0.95);
        rb.ensure_lanes(2);
        for t in 0..4 {
            // two of three rounds pushed: checkpoint lands mid-horizon
            let mut s = step(0.3 * t as f32, 0.1 * t as f32, t == 1);
            s.obs = vec![t as f32, -1.0];
            s.logp = -0.25 * t as f32;
            rb.push(s);
        }
        let mut restored = RolloutBuffer::from_json(&rb.to_json()).unwrap();
        assert_eq!(restored.lanes(), 2);
        assert!(!restored.full());
        for b in [&mut rb, &mut restored] {
            b.push(step(1.0, 0.5, false));
            b.push(step(2.0, 0.6, false));
        }
        let a = rb.finish(&[0.7, 0.8], true);
        let b = restored.finish(&[0.7, 0.8], true);
        assert_eq!(a.size, b.size);
        for (x, y) in a.advantages.iter().zip(&b.advantages) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.returns.iter().zip(&b.returns) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.logp_old, b.logp_old);
        assert_eq!(a.obs, b.obs);
    }

    #[test]
    fn finish_into_reuses_capacity_without_behavior_change() {
        let fill = |rb: &mut RolloutBuffer| {
            for k in 0..4 {
                rb.push(step(k as f32, 0.1 * k as f32, k == 2));
            }
        };
        let mut rb = RolloutBuffer::new(4, 0.99, 0.95);
        let mut reused = RolloutBatch::default();
        fill(&mut rb);
        rb.finish_into(&[0.5], true, &mut reused); // warm the capacity
        fill(&mut rb);
        rb.finish_into(&[0.5], true, &mut reused);
        let mut rb2 = RolloutBuffer::new(4, 0.99, 0.95);
        fill(&mut rb2);
        let fresh = rb2.finish(&[0.5], true);
        assert_eq!(reused.advantages, fresh.advantages);
        assert_eq!(reused.returns, fresh.returns);
        assert_eq!(reused.obs, fresh.obs);
        assert_eq!(reused.logp_old, fresh.logp_old);
        assert_eq!(reused.size, fresh.size);
        assert!(rb.is_empty());
    }
}
