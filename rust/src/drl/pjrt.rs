//! PJRT implementations of the per-algorithm compute traits: parameter
//! marshaling ([`super::network::ParamSet`]) + artifact invocation, with
//! the exact input/output conventions of `python/compile/trainstep.py`.
//!
//! Only compiled with the **`pjrt`** feature (needs the external `xla`
//! bindings and `make artifacts`).  The factory functions at the bottom
//! assemble full agents: they read the artifact's `scaled` metadata to
//! arm or disable the loss-scaling FSM, then wrap the compute in the
//! always-compiled coordination shells (`DqnAgent`, …).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::quant::LossScaler;
use crate::runtime::executor::{literal_f32, literal_i32, scalar_f32, scalar_of, to_vec_f32};
use crate::runtime::{Executor, Runtime};
use crate::util::Rng;

use super::a2c::{A2cAgent, A2cConfig};
use super::compute::{A2cCompute, ComputeBackend, DdpgCompute, DqnCompute, PpoCompute, TrainOut};
use super::ddpg::{DdpgAgent, DdpgConfig};
use super::dqn::{DqnAgent, DqnConfig};
use super::network::ParamSet;
use super::ppo::{PpoAgent, PpoConfig};
use super::replay::Batch;
use super::rollout::RolloutBatch;

fn scaler_from_meta(exe: &Executor) -> LossScaler {
    let scaled = exe.spec().meta.get("scaled").and_then(|b| b.as_bool()).unwrap_or(false);
    if scaled {
        LossScaler::default()
    } else {
        LossScaler::disabled()
    }
}

fn meta_shapes(spec: &crate::runtime::ArtifactSpec, key: &str) -> Result<Vec<Vec<usize>>> {
    let arr = spec
        .meta
        .get(key)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("artifact {}: missing {key}", spec.name))?;
    Ok(arr
        .iter()
        .map(|sh| {
            sh.as_arr()
                .map(|d| d.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default()
        })
        .collect())
}

// ---------------------------------------------------------------- DQN --

/// DQN compute over `<combo>_<mode>_{act,train}` artifacts.
pub struct PjrtDqn {
    act_exe: Arc<Executor>,
    train_exe: Arc<Executor>,
    params: ParamSet,
    target: Vec<xla::Literal>,
    opt: Vec<xla::Literal>,
    obs_shape: Vec<usize>,
}

impl PjrtDqn {
    pub fn new(
        runtime: &mut Runtime,
        combo: &str,
        mode: &str,
        obs_shape: Vec<usize>,
        seed: u64,
    ) -> Result<Self> {
        let act_exe = runtime.load(&format!("{combo}_{mode}_act"))?;
        let train_exe = runtime.load(&format!("{combo}_{mode}_train"))?;
        let shapes = train_exe.spec().param_shapes();
        if shapes.is_empty() {
            return Err(anyhow!("artifact {combo}_{mode}_train has no param_shapes meta"));
        }
        let mut rng = Rng::new(seed ^ 0xD09);
        let params = ParamSet::init(&shapes, &mut rng)?;
        let target = params.clone_literals();
        let opt = ParamSet::opt_state(&shapes)?;
        Ok(PjrtDqn { act_exe, train_exe, params, target, opt, obs_shape })
    }
}

impl ComputeBackend for PjrtDqn {}

impl DqnCompute for PjrtDqn {
    fn qvalues(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<f32>> {
        // The act artifact is lowered at batch 1; run it per lane and
        // concatenate (lane rows are independent, so this matches a
        // natively batched forward).
        let d = obs.len() / lanes;
        let mut shape = vec![1usize];
        shape.extend(&self.obs_shape);
        let mut all = Vec::new();
        for l in 0..lanes {
            let obs_lit = literal_f32(&obs[l * d..(l + 1) * d], &shape)?;
            let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
            inputs.push(&obs_lit);
            let outs = self.act_exe.run(&inputs)?;
            all.extend(to_vec_f32(&outs[0])?);
        }
        Ok(all)
    }

    fn train(&mut self, batch: &Batch, loss_scale: f32) -> Result<TrainOut> {
        let bs = batch.size;
        let mut obs_shape = vec![bs];
        obs_shape.extend(&self.obs_shape);
        let scratch = [
            literal_f32(&batch.obs, &obs_shape)?,
            literal_i32(&batch.actions_i32, &[bs])?,
            literal_f32(&batch.rewards, &[bs])?,
            literal_f32(&batch.next_obs, &obs_shape)?,
            literal_f32(&batch.dones, &[bs])?,
            scalar_f32(loss_scale)?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
        inputs.extend(self.target.iter());
        inputs.extend(self.opt.iter());
        inputs.extend(scratch.iter());
        let mut outs = self.train_exe.run(&inputs)?;
        // outputs: params(k), opt(2k+1), loss, found_inf
        let k = self.params.len();
        let found_inf = scalar_of(&outs.pop().unwrap())? > 0.5;
        let loss = scalar_of(&outs.pop().unwrap())?;
        let opt = outs.split_off(k);
        self.params.replace(outs);
        self.opt = opt;
        Ok(TrainOut { loss, found_inf })
    }

    fn sync_target(&mut self) -> Result<()> {
        self.target = self.params.clone_literals();
        Ok(())
    }
}

// ---------------------------------------------------------------- A2C --

/// A2C compute over `<combo>_<mode>_{act,train}` artifacts.
pub struct PjrtA2c {
    act_exe: Arc<Executor>,
    train_exe: Arc<Executor>,
    params: ParamSet,
    opt: Vec<xla::Literal>,
    obs_dim: usize,
    act_dim: usize,
}

impl PjrtA2c {
    pub fn new(
        runtime: &mut Runtime,
        combo: &str,
        mode: &str,
        obs_dim: usize,
        act_dim: usize,
        seed: u64,
    ) -> Result<Self> {
        let act_exe = runtime.load(&format!("{combo}_{mode}_act"))?;
        let train_exe = runtime.load(&format!("{combo}_{mode}_train"))?;
        let shapes = train_exe.spec().param_shapes();
        let mut rng = Rng::new(seed ^ 0xA2C);
        let params = ParamSet::init(&shapes, &mut rng)?;
        let opt = ParamSet::opt_state(&shapes)?;
        Ok(PjrtA2c { act_exe, train_exe, params, opt, obs_dim, act_dim })
    }
}

impl ComputeBackend for PjrtA2c {}

impl A2cCompute for PjrtA2c {
    fn policy(&mut self, obs: &[f32], lanes: usize) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        // Batch-1 artifact run per lane; log_std is state-independent so
        // the first lane's copy serves all lanes.
        let d = self.obs_dim;
        let mut means = Vec::with_capacity(lanes * self.act_dim);
        let mut values = Vec::with_capacity(lanes);
        let mut log_std = Vec::new();
        for l in 0..lanes {
            let obs_lit = literal_f32(&obs[l * d..(l + 1) * d], &[1, d])?;
            let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
            inputs.push(&obs_lit);
            let outs = self.act_exe.run(&inputs)?;
            means.extend(to_vec_f32(&outs[0])?);
            if l == 0 {
                log_std = to_vec_f32(&outs[1])?;
            }
            values.push(scalar_of(&outs[2])?);
        }
        Ok((means, log_std, values))
    }

    fn train(&mut self, batch: &RolloutBatch, loss_scale: f32) -> Result<TrainOut> {
        let bs = batch.size;
        let scratch = [
            literal_f32(&batch.obs, &[bs, self.obs_dim])?,
            literal_f32(&batch.actions_f32, &[bs, self.act_dim])?,
            literal_f32(&batch.returns, &[bs])?,
            literal_f32(&batch.advantages, &[bs])?,
            scalar_f32(loss_scale)?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
        inputs.extend(self.opt.iter());
        inputs.extend(scratch.iter());
        let mut outs = self.train_exe.run(&inputs)?;
        let k = self.params.len();
        let found_inf = scalar_of(&outs.pop().unwrap())? > 0.5;
        let loss = scalar_of(&outs.pop().unwrap())?;
        let opt = outs.split_off(k);
        self.params.replace(outs);
        self.opt = opt;
        Ok(TrainOut { loss, found_inf })
    }
}

// --------------------------------------------------------------- DDPG --

/// DDPG compute over `<combo>_<mode>_{act,train}` artifacts; the
/// artifact owns the target networks' soft updates.
pub struct PjrtDdpg {
    act_exe: Arc<Executor>,
    train_exe: Arc<Executor>,
    actor: ParamSet,
    critic: ParamSet,
    t_actor: Vec<xla::Literal>,
    t_critic: Vec<xla::Literal>,
    opt_a: Vec<xla::Literal>,
    opt_c: Vec<xla::Literal>,
    obs_dim: usize,
    act_dim: usize,
}

impl PjrtDdpg {
    pub fn new(
        runtime: &mut Runtime,
        combo: &str,
        mode: &str,
        obs_dim: usize,
        act_dim: usize,
        seed: u64,
    ) -> Result<Self> {
        let act_exe = runtime.load(&format!("{combo}_{mode}_act"))?;
        let train_exe = runtime.load(&format!("{combo}_{mode}_train"))?;
        let spec = train_exe.spec();
        let actor_shapes = meta_shapes(spec, "actor_shapes")?;
        let critic_shapes = meta_shapes(spec, "critic_shapes")?;
        let mut rng = Rng::new(seed ^ 0xDD96);
        let actor = ParamSet::init(&actor_shapes, &mut rng)?;
        let critic = ParamSet::init(&critic_shapes, &mut rng)?;
        let t_actor = actor.clone_literals();
        let t_critic = critic.clone_literals();
        let opt_a = ParamSet::opt_state(&actor_shapes)?;
        let opt_c = ParamSet::opt_state(&critic_shapes)?;
        Ok(PjrtDdpg {
            act_exe,
            train_exe,
            actor,
            critic,
            t_actor,
            t_critic,
            opt_a,
            opt_c,
            obs_dim,
            act_dim,
        })
    }
}

impl ComputeBackend for PjrtDdpg {}

impl DdpgCompute for PjrtDdpg {
    fn action(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<f32>> {
        let d = self.obs_dim;
        let mut all = Vec::with_capacity(lanes * self.act_dim);
        for l in 0..lanes {
            let obs_lit = literal_f32(&obs[l * d..(l + 1) * d], &[1, d])?;
            let mut inputs: Vec<&xla::Literal> = self.actor.tensors.iter().collect();
            inputs.push(&obs_lit);
            let outs = self.act_exe.run(&inputs)?;
            all.extend(to_vec_f32(&outs[0])?);
        }
        Ok(all)
    }

    fn train(&mut self, batch: &Batch, loss_scale: f32) -> Result<TrainOut> {
        let bs = batch.size;
        let scratch = [
            literal_f32(&batch.obs, &[bs, self.obs_dim])?,
            literal_f32(&batch.actions_f32, &[bs, self.act_dim])?,
            literal_f32(&batch.rewards, &[bs])?,
            literal_f32(&batch.next_obs, &[bs, self.obs_dim])?,
            literal_f32(&batch.dones, &[bs])?,
            scalar_f32(loss_scale)?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.actor.tensors.iter().collect();
        inputs.extend(self.critic.tensors.iter());
        inputs.extend(self.t_actor.iter());
        inputs.extend(self.t_critic.iter());
        inputs.extend(self.opt_a.iter());
        inputs.extend(self.opt_c.iter());
        inputs.extend(scratch.iter());
        let mut outs = self.train_exe.run(&inputs)?;
        // outputs: actor, critic, t_actor, t_critic, opt_a, opt_c,
        //          closs, aloss, found_inf
        let ka = self.actor.len();
        let kc = self.critic.len();
        let found_inf = scalar_of(&outs.pop().unwrap())? > 0.5;
        let _aloss = scalar_of(&outs.pop().unwrap())?;
        let closs = scalar_of(&outs.pop().unwrap())?;
        let opt_c = outs.split_off(outs.len() - (2 * kc + 1));
        let opt_a = outs.split_off(outs.len() - (2 * ka + 1));
        let t_critic = outs.split_off(outs.len() - kc);
        let t_actor = outs.split_off(outs.len() - ka);
        let critic = outs.split_off(ka);
        self.actor.replace(outs);
        self.critic.replace(critic);
        self.t_actor = t_actor;
        self.t_critic = t_critic;
        self.opt_a = opt_a;
        self.opt_c = opt_c;
        Ok(TrainOut { loss: closs, found_inf })
    }
}

// ---------------------------------------------------------------- PPO --

/// PPO compute over `<combo>_<mode>_{act,train}` artifacts.
pub struct PjrtPpo {
    act_exe: Arc<Executor>,
    train_exe: Arc<Executor>,
    params: ParamSet,
    opt: Vec<xla::Literal>,
    obs_shape: Vec<usize>,
}

impl PjrtPpo {
    pub fn new(
        runtime: &mut Runtime,
        combo: &str,
        mode: &str,
        obs_shape: Vec<usize>,
        seed: u64,
    ) -> Result<Self> {
        let act_exe = runtime.load(&format!("{combo}_{mode}_act"))?;
        let train_exe = runtime.load(&format!("{combo}_{mode}_train"))?;
        let shapes = train_exe.spec().param_shapes();
        let mut rng = Rng::new(seed ^ 0x990);
        let params = ParamSet::init(&shapes, &mut rng)?;
        let opt = ParamSet::opt_state(&shapes)?;
        Ok(PjrtPpo { act_exe, train_exe, params, opt, obs_shape })
    }
}

impl ComputeBackend for PjrtPpo {}

impl PpoCompute for PjrtPpo {
    fn policy(&mut self, obs: &[f32], lanes: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = obs.len() / lanes;
        let mut shape = vec![1usize];
        shape.extend(&self.obs_shape);
        let mut logits = Vec::new();
        let mut values = Vec::with_capacity(lanes);
        for l in 0..lanes {
            let obs_lit = literal_f32(&obs[l * d..(l + 1) * d], &shape)?;
            let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
            inputs.push(&obs_lit);
            let outs = self.act_exe.run(&inputs)?;
            logits.extend(to_vec_f32(&outs[0])?);
            values.push(scalar_of(&outs[1])?);
        }
        Ok((logits, values))
    }

    fn train(&mut self, batch: &RolloutBatch, loss_scale: f32) -> Result<TrainOut> {
        let bs = batch.size;
        let mut obs_shape = vec![bs];
        obs_shape.extend(&self.obs_shape);
        let scratch = [
            literal_f32(&batch.obs, &obs_shape)?,
            literal_i32(&batch.actions_i32, &[bs])?,
            literal_f32(&batch.logp_old, &[bs])?,
            literal_f32(&batch.returns, &[bs])?,
            literal_f32(&batch.advantages, &[bs])?,
            scalar_f32(loss_scale)?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
        inputs.extend(self.opt.iter());
        inputs.extend(scratch.iter());
        let mut outs = self.train_exe.run(&inputs)?;
        let k = self.params.len();
        let found_inf = scalar_of(&outs.pop().unwrap())? > 0.5;
        let loss = scalar_of(&outs.pop().unwrap())?;
        let opt = outs.split_off(k);
        self.params.replace(outs);
        self.opt = opt;
        Ok(TrainOut { loss, found_inf })
    }
}

// ----------------------------------------------------------- factories --

/// Full DQN agent on the PJRT backend (`scaled` meta arms the FSM).
pub fn dqn_agent(
    runtime: &mut Runtime,
    combo: &str,
    mode: &str,
    cfg: DqnConfig,
    seed: u64,
) -> Result<DqnAgent<PjrtDqn>> {
    let compute = PjrtDqn::new(runtime, combo, mode, cfg.obs_shape.clone(), seed)?;
    let scaler = scaler_from_meta(&compute.train_exe);
    Ok(DqnAgent::from_parts(cfg, compute, scaler))
}

/// Full A2C agent on the PJRT backend.
pub fn a2c_agent(
    runtime: &mut Runtime,
    combo: &str,
    mode: &str,
    cfg: A2cConfig,
    seed: u64,
) -> Result<A2cAgent<PjrtA2c>> {
    let compute = PjrtA2c::new(runtime, combo, mode, cfg.obs_dim, cfg.act_dim, seed)?;
    let scaler = scaler_from_meta(&compute.train_exe);
    Ok(A2cAgent::from_parts(cfg, compute, scaler))
}

/// Full DDPG agent on the PJRT backend.
pub fn ddpg_agent(
    runtime: &mut Runtime,
    combo: &str,
    mode: &str,
    cfg: DdpgConfig,
    seed: u64,
) -> Result<DdpgAgent<PjrtDdpg>> {
    let compute = PjrtDdpg::new(runtime, combo, mode, cfg.obs_dim, cfg.act_dim, seed)?;
    let scaler = scaler_from_meta(&compute.train_exe);
    Ok(DdpgAgent::from_parts(cfg, compute, scaler))
}

/// Full PPO agent on the PJRT backend.
pub fn ppo_agent(
    runtime: &mut Runtime,
    combo: &str,
    mode: &str,
    cfg: PpoConfig,
    seed: u64,
) -> Result<PpoAgent<PjrtPpo>> {
    let compute = PjrtPpo::new(runtime, combo, mode, cfg.obs_shape.clone(), seed)?;
    let scaler = scaler_from_meta(&compute.train_exe);
    Ok(PpoAgent::from_parts(cfg, compute, scaler))
}
