//! A2C agent (continuous control): Gaussian policy + value net trained
//! jointly from fixed-horizon GAE rollouts.  Network math is delegated
//! to an [`A2cCompute`] backend (CPU executor or PJRT artifacts).

use anyhow::{ensure, Result};

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::quant::LossScaler;
use crate::util::json::Json;
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::compute::A2cCompute;
use super::rollout::{RolloutBatch, RolloutBuffer, RolloutStep};

#[derive(Clone, Debug)]
pub struct A2cConfig {
    pub horizon: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub gamma: f64,
    pub gae_lambda: f64,
}

impl A2cConfig {
    pub fn for_combo(horizon: usize, obs_dim: usize, act_dim: usize) -> Self {
        A2cConfig { horizon, obs_dim, act_dim, gamma: 0.99, gae_lambda: 0.95 }
    }
}

/// Coordination shell around an [`A2cCompute`] backend.
pub struct A2cAgent<C: A2cCompute> {
    cfg: A2cConfig,
    compute: C,
    rollout: RolloutBuffer,
    scaler: LossScaler,
    scratch: RolloutBatch,
    /// Cached policy outputs from the last `act` (reused in `observe`):
    /// (means lanes × act_dim, log_std act_dim, values lanes).
    last: Option<(Vec<f32>, Vec<f32>, Vec<f32>)>,
    train_steps: u64,
}

impl<C: A2cCompute> A2cAgent<C> {
    pub fn from_parts(cfg: A2cConfig, compute: C, scaler: LossScaler) -> Self {
        let rollout = RolloutBuffer::new(cfg.horizon, cfg.gamma, cfg.gae_lambda);
        A2cAgent {
            cfg,
            compute,
            rollout,
            scaler,
            scratch: RolloutBatch::default(),
            last: None,
            train_steps: 0,
        }
    }

    fn gaussian_logp(a: &[f32], mean: &[f32], log_std: &[f32]) -> f32 {
        const LOG_2PI: f32 = 1.837_877_1;
        a.iter()
            .zip(mean)
            .zip(log_std)
            .map(|((ai, mi), li)| {
                let std = li.exp();
                let z = (ai - mi) / std;
                -0.5 * z * z - li - 0.5 * LOG_2PI
            })
            .sum()
    }

    /// Per-lane bootstrap values for the state after the final round:
    /// 0 where the lane terminated, the value head otherwise.  Skips the
    /// forward entirely when every lane terminated — at `lanes == 1`
    /// that reproduces the scalar path's `if done { 0.0 } else { … }`
    /// exactly (same calls, same inputs).
    fn bootstrap_values(&mut self, next_obs: &[f32], dones: &[bool]) -> Result<Vec<f32>> {
        if dones.iter().all(|&d| d) {
            return Ok(vec![0.0; dones.len()]);
        }
        let mut values = self.compute.policy(next_obs, dones.len())?.2;
        for (v, &d) in values.iter_mut().zip(dones) {
            if d {
                *v = 0.0;
            }
        }
        Ok(values)
    }

    fn train_rollout(&mut self, last_values: &[f32]) -> Result<StepStats> {
        self.rollout.finish_into(last_values, true, &mut self.scratch);
        let scale_used = self.scaler.scale();
        let out = self.compute.train(&self.scratch, scale_used)?;
        if self.scaler.update(out.found_inf) {
            self.train_steps += 1;
        }
        Ok(StepStats { loss: out.loss, found_inf: out.found_inf, loss_scale: scale_used })
    }
}

impl<C: A2cCompute> Agent for A2cAgent<C> {
    fn act(&mut self, obs: &[f32], lanes: usize, rng: &mut Rng) -> Result<Vec<Action>> {
        // One batched policy forward, then per-lane Gaussian draws in
        // lane order — the same RNG stream as the scalar path at
        // `lanes == 1`.
        let (means, log_std, values) = self.compute.policy(obs, lanes)?;
        let ad = self.cfg.act_dim;
        let mut out = Vec::with_capacity(lanes);
        for l in 0..lanes {
            let action: Vec<f32> = means[l * ad..(l + 1) * ad]
                .iter()
                .zip(&log_std)
                .map(|(m, s)| (m + s.exp() * rng.normal() as f32).clamp(-1.0, 1.0))
                .collect();
            out.push(Action::Continuous(action));
        }
        self.last = Some((means, log_std, values));
        Ok(out)
    }

    fn act_greedy(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<Action>> {
        let (means, _, _) = self.compute.policy(obs, lanes)?;
        let ad = self.cfg.act_dim;
        Ok((0..lanes)
            .map(|l| {
                Action::Continuous(
                    means[l * ad..(l + 1) * ad].iter().map(|m| m.clamp(-1.0, 1.0)).collect(),
                )
            })
            .collect())
    }

    fn observe(
        &mut self,
        obs: &[f32],
        actions: &[Action],
        rewards: &[f32],
        next_obs: &[f32],
        dones: &[bool],
        _rng: &mut Rng,
        stats: &mut Vec<StepStats>,
    ) -> Result<()> {
        let lanes = actions.len();
        let ad = self.cfg.act_dim;
        let d = self.cfg.obs_dim;
        self.rollout.ensure_lanes(lanes);
        let (means, log_std, values) = self
            .last
            .take()
            .unwrap_or((vec![0.0; lanes * ad], vec![0.0; ad], vec![0.0; lanes]));
        for l in 0..lanes {
            let a = actions[l].try_continuous()?;
            let logp = Self::gaussian_logp(a, &means[l * ad..(l + 1) * ad], &log_std);
            self.rollout.push(RolloutStep {
                obs: obs[l * d..(l + 1) * d].to_vec(),
                action_i: 0,
                action_c: a.to_vec(),
                logp,
                value: values[l],
                reward: rewards[l],
                done: dones[l],
            });
        }
        if self.rollout.full() {
            let last_values = self.bootstrap_values(next_obs, dones)?;
            stats.push(self.train_rollout(&last_values)?);
        }
        Ok(())
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn exec_policy(&self) -> Option<&ExecPolicy> {
        self.compute.exec_policy()
    }

    fn save_state(&self) -> Result<Json> {
        ensure!(self.last.is_none(), "A2C agent cannot snapshot between act and observe");
        Ok(Json::obj(vec![
            ("compute", self.compute.save_state()?),
            ("rollout", self.rollout.to_json()),
            ("scaler", self.scaler.to_json()),
            ("train_steps", Json::Num(self.train_steps as f64)),
        ]))
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.compute.restore_state(state.req("compute")?)?;
        self.rollout = RolloutBuffer::from_json(state.req("rollout")?)?;
        self.scaler = LossScaler::from_json(state.req("scaler")?)?;
        self.train_steps = state.req_u64("train_steps")?;
        self.last = None;
        Ok(())
    }
}
