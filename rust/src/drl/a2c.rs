//! A2C agent (continuous control): Gaussian policy + value net trained
//! jointly from fixed-horizon GAE rollouts.  Network math is delegated
//! to an [`A2cCompute`] backend (CPU executor or PJRT artifacts).

use anyhow::Result;

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::quant::LossScaler;
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::compute::A2cCompute;
use super::rollout::{RolloutBuffer, RolloutStep};

#[derive(Clone, Debug)]
pub struct A2cConfig {
    pub horizon: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub gamma: f64,
    pub gae_lambda: f64,
}

impl A2cConfig {
    pub fn for_combo(horizon: usize, obs_dim: usize, act_dim: usize) -> Self {
        A2cConfig { horizon, obs_dim, act_dim, gamma: 0.99, gae_lambda: 0.95 }
    }
}

/// Coordination shell around an [`A2cCompute`] backend.
pub struct A2cAgent<C: A2cCompute> {
    cfg: A2cConfig,
    compute: C,
    rollout: RolloutBuffer,
    scaler: LossScaler,
    /// Cached policy outputs from the last `act` (reused in `observe`).
    last: Option<(Vec<f32>, Vec<f32>, f32)>, // (mean, log_std, value)
    train_steps: u64,
}

impl<C: A2cCompute> A2cAgent<C> {
    pub fn from_parts(cfg: A2cConfig, compute: C, scaler: LossScaler) -> Self {
        let rollout = RolloutBuffer::new(cfg.horizon, cfg.gamma, cfg.gae_lambda);
        A2cAgent { cfg, compute, rollout, scaler, last: None, train_steps: 0 }
    }

    fn gaussian_logp(a: &[f32], mean: &[f32], log_std: &[f32]) -> f32 {
        const LOG_2PI: f32 = 1.837_877_1;
        a.iter()
            .zip(mean)
            .zip(log_std)
            .map(|((ai, mi), li)| {
                let std = li.exp();
                let z = (ai - mi) / std;
                -0.5 * z * z - li - 0.5 * LOG_2PI
            })
            .sum()
    }

    fn train_rollout(&mut self, last_value: f32) -> Result<StepStats> {
        let batch = self.rollout.finish(last_value, true);
        let scale_used = self.scaler.scale();
        let out = self.compute.train(&batch, scale_used)?;
        if self.scaler.update(out.found_inf) {
            self.train_steps += 1;
        }
        Ok(StepStats { loss: out.loss, found_inf: out.found_inf, loss_scale: scale_used })
    }
}

impl<C: A2cCompute> Agent for A2cAgent<C> {
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action> {
        let (mean, log_std, value) = self.compute.policy(obs)?;
        let action: Vec<f32> = mean
            .iter()
            .zip(&log_std)
            .map(|(m, l)| (m + l.exp() * rng.normal() as f32).clamp(-1.0, 1.0))
            .collect();
        self.last = Some((mean, log_std, value));
        Ok(Action::Continuous(action))
    }

    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action> {
        let (mean, _, _) = self.compute.policy(obs)?;
        Ok(Action::Continuous(mean.iter().map(|m| m.clamp(-1.0, 1.0)).collect()))
    }

    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        _rng: &mut Rng,
    ) -> Result<Option<StepStats>> {
        let (mean, log_std, value) = self
            .last
            .take()
            .unwrap_or((vec![0.0; self.cfg.act_dim], vec![0.0; self.cfg.act_dim], 0.0));
        let a = action.continuous();
        let logp = Self::gaussian_logp(a, &mean, &log_std);
        self.rollout.push(RolloutStep {
            obs: obs.to_vec(),
            action_i: 0,
            action_c: a.to_vec(),
            logp,
            value,
            reward,
            done,
        });
        if self.rollout.full() {
            let last_value = if done { 0.0 } else { self.compute.policy(next_obs)?.2 };
            return self.train_rollout(last_value).map(Some);
        }
        Ok(None)
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn exec_policy(&self) -> Option<&ExecPolicy> {
        self.compute.exec_policy()
    }
}
