//! A2C agent (continuous control): Gaussian policy + value net trained
//! jointly from fixed-horizon GAE rollouts.

use std::sync::Arc;

use anyhow::Result;

use crate::envs::Action;
use crate::quant::LossScaler;
use crate::runtime::executor::{literal_f32, scalar_f32, scalar_of, to_vec_f32};
use crate::runtime::{Executor, Runtime};
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::network::ParamSet;
use super::rollout::{RolloutBuffer, RolloutStep};

#[derive(Clone, Debug)]
pub struct A2cConfig {
    pub horizon: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub gamma: f64,
    pub gae_lambda: f64,
}

impl A2cConfig {
    pub fn for_combo(horizon: usize, obs_dim: usize, act_dim: usize) -> Self {
        A2cConfig { horizon, obs_dim, act_dim, gamma: 0.99, gae_lambda: 0.95 }
    }
}

pub struct A2cAgent {
    cfg: A2cConfig,
    act_exe: Arc<Executor>,
    train_exe: Arc<Executor>,
    params: ParamSet,
    opt: Vec<xla::Literal>,
    rollout: RolloutBuffer,
    scaler: LossScaler,
    /// Cached policy outputs from the last `act` (reused in `observe`).
    last: Option<(Vec<f32>, Vec<f32>, f32)>, // (mean, log_std, value)
    train_steps: u64,
}

impl A2cAgent {
    pub fn new(
        runtime: &mut Runtime,
        combo: &str,
        mode: &str,
        cfg: A2cConfig,
        seed: u64,
    ) -> Result<Self> {
        let act_exe = runtime.load(&format!("{combo}_{mode}_act"))?;
        let train_exe = runtime.load(&format!("{combo}_{mode}_train"))?;
        let shapes = train_exe.spec().param_shapes();
        let mut rng = Rng::new(seed ^ 0xA2C);
        let params = ParamSet::init(&shapes, &mut rng)?;
        let opt = ParamSet::opt_state(&shapes)?;
        let scaled =
            train_exe.spec().meta.get("scaled").and_then(|b| b.as_bool()).unwrap_or(false);
        let scaler = if scaled { LossScaler::default() } else { LossScaler::disabled() };
        let rollout = RolloutBuffer::new(cfg.horizon, cfg.gamma, cfg.gae_lambda);
        Ok(A2cAgent { cfg, act_exe, train_exe, params, opt, rollout, scaler, last: None, train_steps: 0 })
    }

    fn policy(&self, obs: &[f32]) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let obs_lit = literal_f32(obs, &[1, self.cfg.obs_dim])?;
        let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
        inputs.push(&obs_lit);
        let outs = self.act_exe.run(&inputs)?;
        let mean = to_vec_f32(&outs[0])?;
        let log_std = to_vec_f32(&outs[1])?;
        let value = scalar_of(&outs[2])?;
        Ok((mean, log_std, value))
    }

    fn gaussian_logp(a: &[f32], mean: &[f32], log_std: &[f32]) -> f32 {
        const LOG_2PI: f32 = 1.837_877_1;
        a.iter()
            .zip(mean)
            .zip(log_std)
            .map(|((ai, mi), li)| {
                let std = li.exp();
                let z = (ai - mi) / std;
                -0.5 * z * z - li - 0.5 * LOG_2PI
            })
            .sum()
    }

    fn train_rollout(&mut self, last_value: f32) -> Result<StepStats> {
        let batch = self.rollout.finish(last_value, true);
        let bs = batch.size;
        let scratch = [
            literal_f32(&batch.obs, &[bs, self.cfg.obs_dim])?,
            literal_f32(&batch.actions_f32, &[bs, self.cfg.act_dim])?,
            literal_f32(&batch.returns, &[bs])?,
            literal_f32(&batch.advantages, &[bs])?,
            scalar_f32(self.scaler.scale())?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
        inputs.extend(self.opt.iter());
        inputs.extend(scratch.iter());
        let mut outs = self.train_exe.run(&inputs)?;
        let k = self.params.len();
        let found_inf = scalar_of(&outs.pop().unwrap())? > 0.5;
        let loss = scalar_of(&outs.pop().unwrap())?;
        let opt = outs.split_off(k);
        self.params.replace(outs);
        self.opt = opt;
        if self.scaler.update(found_inf) {
            self.train_steps += 1;
        }
        Ok(StepStats { loss, found_inf, loss_scale: self.scaler.scale() })
    }
}

impl Agent for A2cAgent {
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action> {
        let (mean, log_std, value) = self.policy(obs)?;
        let action: Vec<f32> = mean
            .iter()
            .zip(&log_std)
            .map(|(m, l)| (m + l.exp() * rng.normal() as f32).clamp(-1.0, 1.0))
            .collect();
        self.last = Some((mean, log_std, value));
        Ok(Action::Continuous(action))
    }

    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action> {
        let (mean, _, _) = self.policy(obs)?;
        Ok(Action::Continuous(mean.iter().map(|m| m.clamp(-1.0, 1.0)).collect()))
    }

    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        _rng: &mut Rng,
    ) -> Result<Option<StepStats>> {
        let (mean, log_std, value) =
            self.last.take().unwrap_or((vec![0.0; self.cfg.act_dim], vec![0.0; self.cfg.act_dim], 0.0));
        let a = action.continuous();
        let logp = Self::gaussian_logp(a, &mean, &log_std);
        self.rollout.push(RolloutStep {
            obs: obs.to_vec(),
            action_i: 0,
            action_c: a.to_vec(),
            logp,
            value,
            reward,
            done,
        });
        if self.rollout.full() {
            let last_value = if done { 0.0 } else { self.policy(next_obs)?.2 };
            return self.train_rollout(last_value).map(Some);
        }
        Ok(None)
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }
}
