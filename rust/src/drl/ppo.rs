//! PPO agent (discrete actor-critic): clipped-surrogate updates from GAE
//! rollouts; categorical sampling and log-probabilities here, network
//! math in a [`PpoCompute`] backend.

use anyhow::{ensure, Result};

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::quant::LossScaler;
use crate::util::json::Json;
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::compute::PpoCompute;
use super::rollout::{RolloutBatch, RolloutBuffer, RolloutStep};

#[derive(Clone, Debug)]
pub struct PpoConfig {
    pub horizon: usize,
    pub obs_shape: Vec<usize>,
    pub n_actions: usize,
    pub gamma: f64,
    pub gae_lambda: f64,
    /// Gradient epochs per rollout (same batch re-fed; PPO's ratio
    /// clipping makes re-use safe).
    pub epochs: usize,
}

impl PpoConfig {
    pub fn for_combo(horizon: usize, obs_shape: Vec<usize>, n_actions: usize) -> Self {
        PpoConfig { horizon, obs_shape, n_actions, gamma: 0.99, gae_lambda: 0.95, epochs: 2 }
    }
}

/// Coordination shell around a [`PpoCompute`] backend.
pub struct PpoAgent<C: PpoCompute> {
    cfg: PpoConfig,
    compute: C,
    rollout: RolloutBuffer,
    scaler: LossScaler,
    scratch: RolloutBatch,
    /// Cached `act` outputs (log-probs lanes × n_actions, values lanes).
    last: Option<(Vec<f32>, Vec<f32>)>,
    train_steps: u64,
}

impl<C: PpoCompute> PpoAgent<C> {
    pub fn from_parts(cfg: PpoConfig, compute: C, scaler: LossScaler) -> Self {
        let rollout = RolloutBuffer::new(cfg.horizon, cfg.gamma, cfg.gae_lambda);
        PpoAgent {
            cfg,
            compute,
            rollout,
            scaler,
            scratch: RolloutBatch::default(),
            last: None,
            train_steps: 0,
        }
    }

    fn log_softmax(logits: &[f32]) -> Vec<f32> {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logz = logits.iter().map(|l| (l - max).exp()).sum::<f32>().ln() + max;
        logits.iter().map(|l| l - logz).collect()
    }

    /// Per-lane bootstrap values: 0 where the lane terminated, the value
    /// head otherwise; the forward is skipped entirely when every lane
    /// terminated (scalar-path behavior at `lanes == 1`).
    fn bootstrap_values(&mut self, next_obs: &[f32], dones: &[bool]) -> Result<Vec<f32>> {
        if dones.iter().all(|&d| d) {
            return Ok(vec![0.0; dones.len()]);
        }
        let mut values = self.compute.policy(next_obs, dones.len())?.1;
        for (v, &d) in values.iter_mut().zip(dones) {
            if d {
                *v = 0.0;
            }
        }
        Ok(values)
    }

    /// Run `epochs` optimizer steps over one finished rollout.  The
    /// returned stats aggregate the epochs: `found_inf` is true when
    /// *any* epoch overflowed (so `RunMetrics::overflows` counts
    /// rollouts with at least one overflow), `loss_scale` is the scale
    /// fed to the first epoch (consecutive rollouts therefore expose
    /// every inter-rollout FSM transition, including the first
    /// backoff), and `loss` is the final epoch's.
    fn train_rollout(&mut self, last_values: &[f32]) -> Result<StepStats> {
        self.rollout.finish_into(last_values, true, &mut self.scratch);
        let first_scale = self.scaler.scale();
        let mut any_inf = false;
        let mut loss = 0.0;
        for _ in 0..self.cfg.epochs {
            let out = self.compute.train(&self.scratch, self.scaler.scale())?;
            any_inf |= out.found_inf;
            if self.scaler.update(out.found_inf) {
                self.train_steps += 1;
            }
            loss = out.loss;
        }
        Ok(StepStats { loss, found_inf: any_inf, loss_scale: first_scale })
    }
}

impl<C: PpoCompute> Agent for PpoAgent<C> {
    fn act(&mut self, obs: &[f32], lanes: usize, rng: &mut Rng) -> Result<Vec<Action>> {
        // One batched policy forward, then per-lane categorical draws in
        // lane order (one `uniform()` each) — the scalar RNG stream at
        // `lanes == 1`.
        let (logits, values) = self.compute.policy(obs, lanes)?;
        let na = logits.len() / lanes;
        let mut logp_all = Vec::with_capacity(logits.len());
        let mut out = Vec::with_capacity(lanes);
        for l in 0..lanes {
            let logp = Self::log_softmax(&logits[l * na..(l + 1) * na]);
            let probs: Vec<f64> = logp.iter().map(|x| x.exp() as f64).collect();
            out.push(Action::Discrete(rng.categorical(&probs)));
            logp_all.extend_from_slice(&logp);
        }
        self.last = Some((logp_all, values));
        Ok(out)
    }

    fn act_greedy(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<Action>> {
        let (logits, _) = self.compute.policy(obs, lanes)?;
        let na = logits.len() / lanes;
        Ok((0..lanes)
            .map(|l| {
                let row = &logits[l * na..(l + 1) * na];
                let best = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                Action::Discrete(best)
            })
            .collect())
    }

    fn observe(
        &mut self,
        obs: &[f32],
        actions: &[Action],
        rewards: &[f32],
        next_obs: &[f32],
        dones: &[bool],
        _rng: &mut Rng,
        stats: &mut Vec<StepStats>,
    ) -> Result<()> {
        let lanes = actions.len();
        let na = self.cfg.n_actions;
        let d: usize = self.cfg.obs_shape.iter().product();
        self.rollout.ensure_lanes(lanes);
        let (logp_all, values) =
            self.last.take().unwrap_or((vec![0.0; lanes * na], vec![0.0; lanes]));
        for l in 0..lanes {
            let a = actions[l].try_discrete()?;
            self.rollout.push(RolloutStep {
                obs: obs[l * d..(l + 1) * d].to_vec(),
                action_i: a as i32,
                action_c: vec![],
                logp: logp_all.get(l * na + a).copied().unwrap_or(0.0),
                value: values[l],
                reward: rewards[l],
                done: dones[l],
            });
        }
        if self.rollout.full() {
            let last_values = self.bootstrap_values(next_obs, dones)?;
            stats.push(self.train_rollout(&last_values)?);
        }
        Ok(())
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn exec_policy(&self) -> Option<&ExecPolicy> {
        self.compute.exec_policy()
    }

    fn save_state(&self) -> Result<Json> {
        ensure!(self.last.is_none(), "PPO agent cannot snapshot between act and observe");
        Ok(Json::obj(vec![
            ("compute", self.compute.save_state()?),
            ("rollout", self.rollout.to_json()),
            ("scaler", self.scaler.to_json()),
            ("train_steps", Json::Num(self.train_steps as f64)),
        ]))
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.compute.restore_state(state.req("compute")?)?;
        self.rollout = RolloutBuffer::from_json(state.req("rollout")?)?;
        self.scaler = LossScaler::from_json(state.req("scaler")?)?;
        self.train_steps = state.req_u64("train_steps")?;
        self.last = None;
        Ok(())
    }
}
