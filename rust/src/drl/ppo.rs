//! PPO agent (discrete, conv actor-critic with shared trunk):
//! clipped-surrogate updates from GAE rollouts; categorical sampling and
//! log-probabilities at L3.

use std::sync::Arc;

use anyhow::Result;

use crate::envs::Action;
use crate::quant::LossScaler;
use crate::runtime::executor::{literal_f32, literal_i32, scalar_f32, scalar_of, to_vec_f32};
use crate::runtime::{Executor, Runtime};
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::network::ParamSet;
use super::rollout::{RolloutBuffer, RolloutStep};

#[derive(Clone, Debug)]
pub struct PpoConfig {
    pub horizon: usize,
    pub obs_shape: Vec<usize>,
    pub n_actions: usize,
    pub gamma: f64,
    pub gae_lambda: f64,
    /// Gradient epochs per rollout (same batch re-fed; PPO's ratio
    /// clipping makes re-use safe).
    pub epochs: usize,
}

impl PpoConfig {
    pub fn for_combo(horizon: usize, obs_shape: Vec<usize>, n_actions: usize) -> Self {
        PpoConfig { horizon, obs_shape, n_actions, gamma: 0.99, gae_lambda: 0.95, epochs: 2 }
    }
}

pub struct PpoAgent {
    cfg: PpoConfig,
    act_exe: Arc<Executor>,
    train_exe: Arc<Executor>,
    params: ParamSet,
    opt: Vec<xla::Literal>,
    rollout: RolloutBuffer,
    scaler: LossScaler,
    last: Option<(Vec<f32>, f32)>, // (logits, value) from act()
    train_steps: u64,
}

impl PpoAgent {
    pub fn new(
        runtime: &mut Runtime,
        combo: &str,
        mode: &str,
        cfg: PpoConfig,
        seed: u64,
    ) -> Result<Self> {
        let act_exe = runtime.load(&format!("{combo}_{mode}_act"))?;
        let train_exe = runtime.load(&format!("{combo}_{mode}_train"))?;
        let shapes = train_exe.spec().param_shapes();
        let mut rng = Rng::new(seed ^ 0x990);
        let params = ParamSet::init(&shapes, &mut rng)?;
        let opt = ParamSet::opt_state(&shapes)?;
        let scaled =
            train_exe.spec().meta.get("scaled").and_then(|b| b.as_bool()).unwrap_or(false);
        let scaler = if scaled { LossScaler::default() } else { LossScaler::disabled() };
        let rollout = RolloutBuffer::new(cfg.horizon, cfg.gamma, cfg.gae_lambda);
        Ok(PpoAgent { cfg, act_exe, train_exe, params, opt, rollout, scaler, last: None, train_steps: 0 })
    }

    fn policy(&self, obs: &[f32]) -> Result<(Vec<f32>, f32)> {
        let mut shape = vec![1usize];
        shape.extend(&self.cfg.obs_shape);
        let obs_lit = literal_f32(obs, &shape)?;
        let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
        inputs.push(&obs_lit);
        let outs = self.act_exe.run(&inputs)?;
        Ok((to_vec_f32(&outs[0])?, scalar_of(&outs[1])?))
    }

    fn log_softmax(logits: &[f32]) -> Vec<f32> {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logz = logits.iter().map(|l| (l - max).exp()).sum::<f32>().ln() + max;
        logits.iter().map(|l| l - logz).collect()
    }

    fn train_rollout(&mut self, last_value: f32) -> Result<StepStats> {
        let batch = self.rollout.finish(last_value, true);
        let bs = batch.size;
        let mut obs_shape = vec![bs];
        obs_shape.extend(&self.cfg.obs_shape);
        let mut stats = StepStats { loss: 0.0, found_inf: false, loss_scale: self.scaler.scale() };
        for _ in 0..self.cfg.epochs {
            let scratch = [
                literal_f32(&batch.obs, &obs_shape)?,
                literal_i32(&batch.actions_i32, &[bs])?,
                literal_f32(&batch.logp_old, &[bs])?,
                literal_f32(&batch.returns, &[bs])?,
                literal_f32(&batch.advantages, &[bs])?,
                scalar_f32(self.scaler.scale())?,
            ];
            let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
            inputs.extend(self.opt.iter());
            inputs.extend(scratch.iter());
            let mut outs = self.train_exe.run(&inputs)?;
            let k = self.params.len();
            let found_inf = scalar_of(&outs.pop().unwrap())? > 0.5;
            let loss = scalar_of(&outs.pop().unwrap())?;
            let opt = outs.split_off(k);
            self.params.replace(outs);
            self.opt = opt;
            if self.scaler.update(found_inf) {
                self.train_steps += 1;
            }
            stats = StepStats { loss, found_inf, loss_scale: self.scaler.scale() };
        }
        Ok(stats)
    }
}

impl Agent for PpoAgent {
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action> {
        let (logits, value) = self.policy(obs)?;
        let logp = Self::log_softmax(&logits);
        let probs: Vec<f64> = logp.iter().map(|l| l.exp() as f64).collect();
        let a = rng.categorical(&probs);
        self.last = Some((logp, value));
        Ok(Action::Discrete(a))
    }

    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action> {
        let (logits, _) = self.policy(obs)?;
        let best = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok(Action::Discrete(best))
    }

    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        _rng: &mut Rng,
    ) -> Result<Option<StepStats>> {
        let a = action.discrete();
        let (logp_all, value) = self
            .last
            .take()
            .unwrap_or((vec![0.0; self.cfg.n_actions], 0.0));
        self.rollout.push(RolloutStep {
            obs: obs.to_vec(),
            action_i: a as i32,
            action_c: vec![],
            logp: logp_all.get(a).copied().unwrap_or(0.0),
            value,
            reward,
            done,
        });
        if self.rollout.full() {
            let last_value = if done { 0.0 } else { self.policy(next_obs)?.1 };
            return self.train_rollout(last_value).map(Some);
        }
        Ok(None)
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }
}
