//! PPO agent (discrete actor-critic): clipped-surrogate updates from GAE
//! rollouts; categorical sampling and log-probabilities here, network
//! math in a [`PpoCompute`] backend.

use anyhow::Result;

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::quant::LossScaler;
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::compute::PpoCompute;
use super::rollout::{RolloutBuffer, RolloutStep};

#[derive(Clone, Debug)]
pub struct PpoConfig {
    pub horizon: usize,
    pub obs_shape: Vec<usize>,
    pub n_actions: usize,
    pub gamma: f64,
    pub gae_lambda: f64,
    /// Gradient epochs per rollout (same batch re-fed; PPO's ratio
    /// clipping makes re-use safe).
    pub epochs: usize,
}

impl PpoConfig {
    pub fn for_combo(horizon: usize, obs_shape: Vec<usize>, n_actions: usize) -> Self {
        PpoConfig { horizon, obs_shape, n_actions, gamma: 0.99, gae_lambda: 0.95, epochs: 2 }
    }
}

/// Coordination shell around a [`PpoCompute`] backend.
pub struct PpoAgent<C: PpoCompute> {
    cfg: PpoConfig,
    compute: C,
    rollout: RolloutBuffer,
    scaler: LossScaler,
    last: Option<(Vec<f32>, f32)>, // (log-probs, value) from act()
    train_steps: u64,
}

impl<C: PpoCompute> PpoAgent<C> {
    pub fn from_parts(cfg: PpoConfig, compute: C, scaler: LossScaler) -> Self {
        let rollout = RolloutBuffer::new(cfg.horizon, cfg.gamma, cfg.gae_lambda);
        PpoAgent { cfg, compute, rollout, scaler, last: None, train_steps: 0 }
    }

    fn log_softmax(logits: &[f32]) -> Vec<f32> {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logz = logits.iter().map(|l| (l - max).exp()).sum::<f32>().ln() + max;
        logits.iter().map(|l| l - logz).collect()
    }

    /// Run `epochs` optimizer steps over one finished rollout.  The
    /// returned stats aggregate the epochs: `found_inf` is true when
    /// *any* epoch overflowed (so `RunMetrics::overflows` counts
    /// rollouts with at least one overflow), `loss_scale` is the scale
    /// fed to the first epoch (consecutive rollouts therefore expose
    /// every inter-rollout FSM transition, including the first
    /// backoff), and `loss` is the final epoch's.
    fn train_rollout(&mut self, last_value: f32) -> Result<StepStats> {
        let batch = self.rollout.finish(last_value, true);
        let first_scale = self.scaler.scale();
        let mut any_inf = false;
        let mut loss = 0.0;
        for _ in 0..self.cfg.epochs {
            let out = self.compute.train(&batch, self.scaler.scale())?;
            any_inf |= out.found_inf;
            if self.scaler.update(out.found_inf) {
                self.train_steps += 1;
            }
            loss = out.loss;
        }
        Ok(StepStats { loss, found_inf: any_inf, loss_scale: first_scale })
    }
}

impl<C: PpoCompute> Agent for PpoAgent<C> {
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action> {
        let (logits, value) = self.compute.policy(obs)?;
        let logp = Self::log_softmax(&logits);
        let probs: Vec<f64> = logp.iter().map(|l| l.exp() as f64).collect();
        let a = rng.categorical(&probs);
        self.last = Some((logp, value));
        Ok(Action::Discrete(a))
    }

    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action> {
        let (logits, _) = self.compute.policy(obs)?;
        let best = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok(Action::Discrete(best))
    }

    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        _rng: &mut Rng,
    ) -> Result<Option<StepStats>> {
        let a = action.discrete();
        let (logp_all, value) =
            self.last.take().unwrap_or((vec![0.0; self.cfg.n_actions], 0.0));
        self.rollout.push(RolloutStep {
            obs: obs.to_vec(),
            action_i: a as i32,
            action_c: vec![],
            logp: logp_all.get(a).copied().unwrap_or(0.0),
            value,
            reward,
            done,
        });
        if self.rollout.full() {
            let last_value = if done { 0.0 } else { self.compute.policy(next_obs)?.1 };
            return self.train_rollout(last_value).map(Some);
        }
        Ok(None)
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn exec_policy(&self) -> Option<&ExecPolicy> {
        self.compute.exec_policy()
    }
}
