//! DDPG agent: deterministic actor + Q critic with target networks and
//! soft updates (inside the artifact), OU exploration noise at L3.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::envs::Action;
use crate::quant::LossScaler;
use crate::runtime::executor::{literal_f32, scalar_f32, scalar_of, to_vec_f32};
use crate::runtime::{Executor, Runtime};
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::network::ParamSet;
use super::replay::{ReplayBuffer, StoredAction};

#[derive(Clone, Debug)]
pub struct DdpgConfig {
    pub batch: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub replay_capacity: usize,
    pub warmup: usize,
    pub train_every: usize,
    /// OU noise parameters.
    pub ou_theta: f64,
    pub ou_sigma: f64,
}

impl DdpgConfig {
    pub fn for_combo(batch: usize, obs_dim: usize, act_dim: usize) -> Self {
        DdpgConfig {
            batch,
            obs_dim,
            act_dim,
            replay_capacity: 50_000,
            warmup: 1_000,
            train_every: 1,
            ou_theta: 0.15,
            ou_sigma: 0.2,
        }
    }
}

pub struct DdpgAgent {
    cfg: DdpgConfig,
    act_exe: Arc<Executor>,
    train_exe: Arc<Executor>,
    actor: ParamSet,
    critic: ParamSet,
    t_actor: Vec<xla::Literal>,
    t_critic: Vec<xla::Literal>,
    opt_a: Vec<xla::Literal>,
    opt_c: Vec<xla::Literal>,
    replay: ReplayBuffer,
    scaler: LossScaler,
    ou_state: Vec<f64>,
    env_steps: u64,
    train_steps: u64,
}

impl DdpgAgent {
    pub fn new(
        runtime: &mut Runtime,
        combo: &str,
        mode: &str,
        cfg: DdpgConfig,
        seed: u64,
    ) -> Result<Self> {
        let act_exe = runtime.load(&format!("{combo}_{mode}_act"))?;
        let train_exe = runtime.load(&format!("{combo}_{mode}_train"))?;
        let spec = train_exe.spec();
        let actor_shapes = meta_shapes(spec, "actor_shapes")?;
        let critic_shapes = meta_shapes(spec, "critic_shapes")?;
        let mut rng = Rng::new(seed ^ 0xDD96);
        let actor = ParamSet::init(&actor_shapes, &mut rng)?;
        let critic = ParamSet::init(&critic_shapes, &mut rng)?;
        let t_actor = actor.clone_literals();
        let t_critic = critic.clone_literals();
        let opt_a = ParamSet::opt_state(&actor_shapes)?;
        let opt_c = ParamSet::opt_state(&critic_shapes)?;
        let scaled =
            spec.meta.get("scaled").and_then(|b| b.as_bool()).unwrap_or(false);
        let scaler = if scaled { LossScaler::default() } else { LossScaler::disabled() };
        let replay = ReplayBuffer::new(cfg.replay_capacity, cfg.obs_dim);
        let ou_state = vec![0.0; cfg.act_dim];
        Ok(DdpgAgent {
            cfg,
            act_exe,
            train_exe,
            actor,
            critic,
            t_actor,
            t_critic,
            opt_a,
            opt_c,
            replay,
            scaler,
            ou_state,
            env_steps: 0,
            train_steps: 0,
        })
    }

    fn policy(&self, obs: &[f32]) -> Result<Vec<f32>> {
        let obs_lit = literal_f32(obs, &[1, self.cfg.obs_dim])?;
        let mut inputs: Vec<&xla::Literal> = self.actor.tensors.iter().collect();
        inputs.push(&obs_lit);
        let outs = self.act_exe.run(&inputs)?;
        to_vec_f32(&outs[0])
    }

    fn ou_noise(&mut self, rng: &mut Rng) -> Vec<f64> {
        for x in self.ou_state.iter_mut() {
            *x += -self.cfg.ou_theta * *x + self.cfg.ou_sigma * rng.normal();
        }
        self.ou_state.clone()
    }

    fn train_batch(&mut self, rng: &mut Rng) -> Result<StepStats> {
        let bs = self.cfg.batch;
        let batch = self.replay.sample(bs, rng);
        let scratch = [
            literal_f32(&batch.obs, &[bs, self.cfg.obs_dim])?,
            literal_f32(&batch.actions_f32, &[bs, self.cfg.act_dim])?,
            literal_f32(&batch.rewards, &[bs])?,
            literal_f32(&batch.next_obs, &[bs, self.cfg.obs_dim])?,
            literal_f32(&batch.dones, &[bs])?,
            scalar_f32(self.scaler.scale())?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.actor.tensors.iter().collect();
        inputs.extend(self.critic.tensors.iter());
        inputs.extend(self.t_actor.iter());
        inputs.extend(self.t_critic.iter());
        inputs.extend(self.opt_a.iter());
        inputs.extend(self.opt_c.iter());
        inputs.extend(scratch.iter());
        let mut outs = self.train_exe.run(&inputs)?;
        // outputs: actor, critic, t_actor, t_critic, opt_a, opt_c,
        //          closs, aloss, found_inf
        let ka = self.actor.len();
        let kc = self.critic.len();
        let found_inf = scalar_of(&outs.pop().unwrap())? > 0.5;
        let _aloss = scalar_of(&outs.pop().unwrap())?;
        let closs = scalar_of(&outs.pop().unwrap())?;
        let opt_c = outs.split_off(outs.len() - (2 * kc + 1));
        let opt_a = outs.split_off(outs.len() - (2 * ka + 1));
        let t_critic = outs.split_off(outs.len() - kc);
        let t_actor = outs.split_off(outs.len() - ka);
        let critic = outs.split_off(ka);
        self.actor.replace(outs);
        self.critic.replace(critic);
        self.t_actor = t_actor;
        self.t_critic = t_critic;
        self.opt_a = opt_a;
        self.opt_c = opt_c;
        if self.scaler.update(found_inf) {
            self.train_steps += 1;
        }
        Ok(StepStats { loss: closs, found_inf, loss_scale: self.scaler.scale() })
    }
}

fn meta_shapes(
    spec: &crate::runtime::ArtifactSpec,
    key: &str,
) -> Result<Vec<Vec<usize>>> {
    let arr = spec
        .meta
        .get(key)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("artifact {}: missing {key}", spec.name))?;
    Ok(arr
        .iter()
        .map(|sh| {
            sh.as_arr()
                .map(|d| d.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default()
        })
        .collect())
}

impl Agent for DdpgAgent {
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action> {
        self.env_steps += 1;
        let mut a = self.policy(obs)?;
        let noise = self.ou_noise(rng);
        for (ai, ni) in a.iter_mut().zip(noise) {
            *ai = (*ai + ni as f32).clamp(-1.0, 1.0);
        }
        Ok(Action::Continuous(a))
    }

    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action> {
        Ok(Action::Continuous(self.policy(obs)?))
    }

    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        rng: &mut Rng,
    ) -> Result<Option<StepStats>> {
        self.replay.push(
            obs,
            StoredAction::Continuous(action.continuous().to_vec()),
            reward,
            next_obs,
            done,
        );
        if done {
            self.ou_state.iter_mut().for_each(|x| *x = 0.0);
        }
        if self.replay.len() >= self.cfg.warmup
            && self.env_steps % self.cfg.train_every as u64 == 0
        {
            return self.train_batch(rng).map(Some);
        }
        Ok(None)
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }
}
