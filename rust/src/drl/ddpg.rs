//! DDPG agent: deterministic actor + Q critic with target networks and
//! soft updates (inside the compute backend), OU exploration noise here
//! at the coordination layer.

use anyhow::Result;

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::quant::LossScaler;
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::compute::DdpgCompute;
use super::replay::{ReplayBuffer, StoredAction};

#[derive(Clone, Debug)]
pub struct DdpgConfig {
    pub batch: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub replay_capacity: usize,
    pub warmup: usize,
    pub train_every: usize,
    /// OU noise parameters.
    pub ou_theta: f64,
    pub ou_sigma: f64,
}

impl DdpgConfig {
    pub fn for_combo(batch: usize, obs_dim: usize, act_dim: usize) -> Self {
        DdpgConfig {
            batch,
            obs_dim,
            act_dim,
            replay_capacity: 50_000,
            warmup: 1_000,
            train_every: 1,
            ou_theta: 0.15,
            ou_sigma: 0.2,
        }
    }
}

/// Coordination shell around a [`DdpgCompute`] backend.
pub struct DdpgAgent<C: DdpgCompute> {
    cfg: DdpgConfig,
    compute: C,
    replay: ReplayBuffer,
    scaler: LossScaler,
    ou_state: Vec<f64>,
    env_steps: u64,
    train_steps: u64,
}

impl<C: DdpgCompute> DdpgAgent<C> {
    pub fn from_parts(cfg: DdpgConfig, compute: C, scaler: LossScaler) -> Self {
        let replay = ReplayBuffer::new(cfg.replay_capacity, cfg.obs_dim);
        let ou_state = vec![0.0; cfg.act_dim];
        DdpgAgent { cfg, compute, replay, scaler, ou_state, env_steps: 0, train_steps: 0 }
    }

    fn ou_noise(&mut self, rng: &mut Rng) -> Vec<f64> {
        for x in self.ou_state.iter_mut() {
            *x += -self.cfg.ou_theta * *x + self.cfg.ou_sigma * rng.normal();
        }
        self.ou_state.clone()
    }

    fn train_batch(&mut self, rng: &mut Rng) -> Result<StepStats> {
        let batch = self.replay.sample(self.cfg.batch, rng);
        let scale_used = self.scaler.scale();
        let out = self.compute.train(&batch, scale_used)?;
        if self.scaler.update(out.found_inf) {
            self.train_steps += 1;
        }
        Ok(StepStats { loss: out.loss, found_inf: out.found_inf, loss_scale: scale_used })
    }
}

impl<C: DdpgCompute> Agent for DdpgAgent<C> {
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action> {
        self.env_steps += 1;
        let mut a = self.compute.action(obs)?;
        let noise = self.ou_noise(rng);
        for (ai, ni) in a.iter_mut().zip(noise) {
            *ai = (*ai + ni as f32).clamp(-1.0, 1.0);
        }
        Ok(Action::Continuous(a))
    }

    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action> {
        Ok(Action::Continuous(self.compute.action(obs)?))
    }

    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        rng: &mut Rng,
    ) -> Result<Option<StepStats>> {
        self.replay.push(
            obs,
            StoredAction::Continuous(action.continuous().to_vec()),
            reward,
            next_obs,
            done,
        );
        if done {
            self.ou_state.iter_mut().for_each(|x| *x = 0.0);
        }
        if self.replay.len() >= self.cfg.warmup
            && self.env_steps % self.cfg.train_every as u64 == 0
        {
            return self.train_batch(rng).map(Some);
        }
        Ok(None)
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn exec_policy(&self) -> Option<&ExecPolicy> {
        self.compute.exec_policy()
    }
}
