//! DDPG agent: deterministic actor + Q critic with target networks and
//! soft updates (inside the compute backend), OU exploration noise here
//! at the coordination layer.

use anyhow::{ensure, Result};

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::quant::LossScaler;
use crate::util::json::{hex_f64s, parse_hex_f64s, Json};
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::compute::DdpgCompute;
use super::replay::{Batch, ReplayBuffer, StoredAction};

#[derive(Clone, Debug)]
pub struct DdpgConfig {
    pub batch: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub replay_capacity: usize,
    pub warmup: usize,
    pub train_every: usize,
    /// OU noise parameters.
    pub ou_theta: f64,
    pub ou_sigma: f64,
}

impl DdpgConfig {
    pub fn for_combo(batch: usize, obs_dim: usize, act_dim: usize) -> Self {
        DdpgConfig {
            batch,
            obs_dim,
            act_dim,
            replay_capacity: 50_000,
            warmup: 1_000,
            train_every: 1,
            ou_theta: 0.15,
            ou_sigma: 0.2,
        }
    }
}

/// Coordination shell around a [`DdpgCompute`] backend.
pub struct DdpgAgent<C: DdpgCompute> {
    cfg: DdpgConfig,
    compute: C,
    replay: ReplayBuffer,
    scaler: LossScaler,
    scratch: Batch,
    /// One OU process per actor lane, reset lane-locally on episode end.
    ou_states: Vec<Vec<f64>>,
    env_steps: u64,
    /// Replay pushes — drives the `train_every` cadence per observation
    /// (equal to `env_steps` at `lanes == 1`).
    obs_steps: u64,
    train_steps: u64,
}

impl<C: DdpgCompute> DdpgAgent<C> {
    pub fn from_parts(cfg: DdpgConfig, compute: C, scaler: LossScaler) -> Self {
        let replay = ReplayBuffer::new(cfg.replay_capacity, cfg.obs_dim);
        let ou_states = vec![vec![0.0; cfg.act_dim]];
        DdpgAgent {
            cfg,
            compute,
            replay,
            scaler,
            scratch: Batch::default(),
            ou_states,
            env_steps: 0,
            obs_steps: 0,
            train_steps: 0,
        }
    }

    fn ensure_lanes(&mut self, lanes: usize) {
        while self.ou_states.len() < lanes {
            self.ou_states.push(vec![0.0; self.cfg.act_dim]);
        }
    }

    fn train_batch(&mut self, rng: &mut Rng) -> Result<StepStats> {
        self.replay.sample_into(self.cfg.batch, rng, &mut self.scratch);
        let scale_used = self.scaler.scale();
        let out = self.compute.train(&self.scratch, scale_used)?;
        if self.scaler.update(out.found_inf) {
            self.train_steps += 1;
        }
        Ok(StepStats { loss: out.loss, found_inf: out.found_inf, loss_scale: scale_used })
    }
}

impl<C: DdpgCompute> Agent for DdpgAgent<C> {
    fn act(&mut self, obs: &[f32], lanes: usize, rng: &mut Rng) -> Result<Vec<Action>> {
        self.ensure_lanes(lanes);
        // One batched actor forward (RNG-free) before the per-lane OU
        // draws — same order as the scalar path at `lanes == 1`.
        let a = self.compute.action(obs, lanes)?;
        let ad = self.cfg.act_dim;
        let mut out = Vec::with_capacity(lanes);
        for l in 0..lanes {
            self.env_steps += 1;
            let mut al = a[l * ad..(l + 1) * ad].to_vec();
            for (ai, x) in al.iter_mut().zip(self.ou_states[l].iter_mut()) {
                *x += -self.cfg.ou_theta * *x + self.cfg.ou_sigma * rng.normal();
                *ai = (*ai + *x as f32).clamp(-1.0, 1.0);
            }
            out.push(Action::Continuous(al));
        }
        Ok(out)
    }

    fn act_greedy(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<Action>> {
        let a = self.compute.action(obs, lanes)?;
        let ad = self.cfg.act_dim;
        Ok((0..lanes).map(|l| Action::Continuous(a[l * ad..(l + 1) * ad].to_vec())).collect())
    }

    fn observe(
        &mut self,
        obs: &[f32],
        actions: &[Action],
        rewards: &[f32],
        next_obs: &[f32],
        dones: &[bool],
        rng: &mut Rng,
        stats: &mut Vec<StepStats>,
    ) -> Result<()> {
        let lanes = actions.len();
        self.ensure_lanes(lanes);
        let d = self.cfg.obs_dim;
        for l in 0..lanes {
            let a = actions[l].try_continuous()?.to_vec();
            self.replay.push(
                &obs[l * d..(l + 1) * d],
                StoredAction::Continuous(a),
                rewards[l],
                &next_obs[l * d..(l + 1) * d],
                dones[l],
            );
            if dones[l] {
                self.ou_states[l].iter_mut().for_each(|x| *x = 0.0);
            }
            self.obs_steps += 1;
            if self.replay.len() >= self.cfg.warmup
                && self.obs_steps % self.cfg.train_every as u64 == 0
            {
                stats.push(self.train_batch(rng)?);
            }
        }
        Ok(())
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn exec_policy(&self) -> Option<&ExecPolicy> {
        self.compute.exec_policy()
    }

    fn save_state(&self) -> Result<Json> {
        let ou = self.ou_states.iter().map(|s| Json::Str(hex_f64s(s))).collect();
        Ok(Json::obj(vec![
            ("compute", self.compute.save_state()?),
            ("replay", self.replay.to_json()),
            ("scaler", self.scaler.to_json()),
            ("ou", Json::Arr(ou)),
            ("env_steps", Json::Num(self.env_steps as f64)),
            ("obs_steps", Json::Num(self.obs_steps as f64)),
            ("train_steps", Json::Num(self.train_steps as f64)),
        ]))
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.compute.restore_state(state.req("compute")?)?;
        self.replay = ReplayBuffer::from_json(state.req("replay")?)?;
        self.scaler = LossScaler::from_json(state.req("scaler")?)?;
        let ou = state
            .req_arr("ou")?
            .iter()
            .map(|e| {
                let s =
                    e.as_str().ok_or_else(|| anyhow::anyhow!("ddpg state: bad OU entry"))?;
                Ok(parse_hex_f64s(s)?)
            })
            .collect::<Result<Vec<Vec<f64>>>>()?;
        ensure!(!ou.is_empty(), "ddpg state: OU lanes missing");
        ensure!(
            ou.iter().all(|s| s.len() == self.cfg.act_dim),
            "ddpg state: OU dimension mismatch"
        );
        self.ou_states = ou;
        self.env_steps = state.req_u64("env_steps")?;
        self.obs_steps = state.req_u64("obs_steps")?;
        self.train_steps = state.req_u64("train_steps")?;
        Ok(())
    }
}
