//! Parameter/optimizer-state marshaling between L3 and the artifacts.
//!
//! The convention (python/compile/trainstep.py): parameters are flat
//! positional lists of f32 tensors; optimizer state is
//! `[m..., v..., t]`.  L3 owns initialization (He-uniform weights, zero
//! biases — `nets.init_scale` documents the same rule on the python
//! side) and keeps everything as `xla::Literal`s between steps so the
//! hot path never round-trips through host Vec<f32>.

use anyhow::Result;

use crate::runtime::executor::{literal_f32, to_vec_f32};
use crate::util::Rng;

/// A flat, ordered set of parameter tensors resident as literals.
pub struct ParamSet {
    pub shapes: Vec<Vec<usize>>,
    pub tensors: Vec<xla::Literal>,
}

impl ParamSet {
    /// He-uniform init for ≥2-D tensors (fan-in = product of all dims but
    /// the last), zeros for 1-D (biases, log_std).
    pub fn init(shapes: &[Vec<usize>], rng: &mut Rng) -> Result<ParamSet> {
        let mut tensors = Vec::with_capacity(shapes.len());
        for sh in shapes {
            let elems: usize = sh.iter().product();
            let data = if sh.len() >= 2 {
                let fan_in: usize = sh[..sh.len() - 1].iter().product();
                rng.he_uniform(elems, fan_in)
            } else {
                vec![0.0f32; elems]
            };
            tensors.push(literal_f32(&data, sh)?);
        }
        Ok(ParamSet { shapes: shapes.to_vec(), tensors })
    }

    /// Zero tensors of the same shapes (Adam m/v init).
    pub fn zeros_like(shapes: &[Vec<usize>]) -> Result<Vec<xla::Literal>> {
        shapes
            .iter()
            .map(|sh| {
                let elems: usize = sh.iter().product();
                literal_f32(&vec![0.0; elems], sh)
            })
            .collect()
    }

    /// Fresh optimizer state `[m..., v..., t]` for these shapes.
    pub fn opt_state(shapes: &[Vec<usize>]) -> Result<Vec<xla::Literal>> {
        let mut st = Self::zeros_like(shapes)?;
        st.extend(Self::zeros_like(shapes)?);
        st.push(literal_f32(&[0.0], &[])?);
        Ok(st)
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Deep copy (target-network snapshot).
    pub fn clone_literals(&self) -> Vec<xla::Literal> {
        self.tensors.to_vec()
    }

    /// Replace the resident tensors (after a train step returns the
    /// updated params).
    pub fn replace(&mut self, tensors: Vec<xla::Literal>) {
        debug_assert_eq!(tensors.len(), self.tensors.len());
        self.tensors = tensors;
    }

    /// Host readout (telemetry / checkpoints).
    pub fn to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.tensors.iter().map(to_vec_f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes_and_ranges() {
        let shapes = vec![vec![4, 64], vec![64], vec![64, 2], vec![2]];
        let mut rng = Rng::new(7);
        let ps = ParamSet::init(&shapes, &mut rng).unwrap();
        assert_eq!(ps.len(), 4);
        let host = ps.to_host().unwrap();
        let lim0 = (6.0f32 / 4.0).sqrt();
        assert!(host[0].iter().all(|x| x.abs() <= lim0));
        assert!(host[0].iter().any(|&x| x != 0.0));
        assert!(host[1].iter().all(|&x| x == 0.0)); // bias zeros
        assert_eq!(host[0].len(), 256);
    }

    #[test]
    fn conv_fan_in() {
        // HWIO kernel (4,4,4,8): fan_in = 64 like python init_scale
        let shapes = vec![vec![4, 4, 4, 8]];
        let mut rng = Rng::new(8);
        let ps = ParamSet::init(&shapes, &mut rng).unwrap();
        let host = ps.to_host().unwrap();
        let lim = (6.0f32 / 64.0).sqrt();
        assert!(host[0].iter().all(|x| x.abs() <= lim));
    }

    #[test]
    fn opt_state_layout() {
        let shapes = vec![vec![2, 2], vec![2]];
        let st = ParamSet::opt_state(&shapes).unwrap();
        assert_eq!(st.len(), 5); // m0 m1 v0 v1 t
        assert_eq!(st[4].element_count(), 1);
    }

    #[test]
    fn deterministic_init() {
        let shapes = vec![vec![3, 3]];
        let a = ParamSet::init(&shapes, &mut Rng::new(1)).unwrap().to_host().unwrap();
        let b = ParamSet::init(&shapes, &mut Rng::new(1)).unwrap().to_host().unwrap();
        assert_eq!(a, b);
    }
}
