//! DQN agent (paper Eq. 1): ε-greedy exploration, uniform replay,
//! periodic target-network sync, loss-scaling FSM.  All network math is
//! delegated to a [`DqnCompute`] backend — the CPU executor
//! ([`crate::exec::models::CpuDqn`], always available) or the PJRT
//! artifacts ([`super::pjrt`], `pjrt` feature).  Works for both MLP
//! (CartPole) and conv (mini-Breakout) combos.

use anyhow::Result;

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::quant::LossScaler;
use crate::util::json::Json;
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::compute::DqnCompute;
use super::replay::{Batch, ReplayBuffer, StoredAction};

/// DQN hyper-parameters (coordinator-side; the compute backend owns
/// lr/γ).
#[derive(Clone, Debug)]
pub struct DqnConfig {
    pub batch: usize,
    pub obs_shape: Vec<usize>,
    pub n_actions: usize,
    pub replay_capacity: usize,
    pub warmup: usize,
    pub train_every: usize,
    pub target_sync_every: u64,
    pub eps_start: f64,
    pub eps_end: f64,
    pub eps_decay_steps: f64,
}

impl DqnConfig {
    pub fn for_combo(batch: usize, obs_shape: Vec<usize>, n_actions: usize) -> Self {
        DqnConfig {
            batch,
            obs_shape,
            n_actions,
            replay_capacity: 20_000,
            warmup: 500,
            train_every: 1,
            target_sync_every: 200,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_steps: 4_000.0,
        }
    }

    pub(crate) fn obs_dim(&self) -> usize {
        self.obs_shape.iter().product()
    }
}

/// Coordination shell around a [`DqnCompute`] backend.
pub struct DqnAgent<C: DqnCompute> {
    cfg: DqnConfig,
    compute: C,
    replay: ReplayBuffer,
    scaler: LossScaler,
    scratch: Batch,
    env_steps: u64,
    /// Transitions pushed into replay — drives the `train_every` cadence
    /// per observation (equal to `env_steps` at `lanes == 1`, since
    /// `act` and `observe` alternate once per round).
    obs_steps: u64,
    train_steps: u64,
}

impl<C: DqnCompute> DqnAgent<C> {
    /// Assemble from a ready compute backend and an armed (or disabled)
    /// loss scaler.
    pub fn from_parts(cfg: DqnConfig, compute: C, scaler: LossScaler) -> Self {
        let replay = ReplayBuffer::new(cfg.replay_capacity, cfg.obs_dim());
        DqnAgent {
            cfg,
            compute,
            replay,
            scaler,
            scratch: Batch::default(),
            env_steps: 0,
            obs_steps: 0,
            train_steps: 0,
        }
    }

    fn epsilon(&self) -> f64 {
        let frac = (self.env_steps as f64 / self.cfg.eps_decay_steps).min(1.0);
        self.cfg.eps_start + (self.cfg.eps_end - self.cfg.eps_start) * frac
    }

    fn train_batch(&mut self, rng: &mut Rng) -> Result<StepStats> {
        self.replay.sample_into(self.cfg.batch, rng, &mut self.scratch);
        let scale_used = self.scaler.scale();
        let out = self.compute.train(&self.scratch, scale_used)?;
        let applied = self.scaler.update(out.found_inf);
        if applied {
            self.train_steps += 1;
            if self.train_steps % self.cfg.target_sync_every == 0 {
                self.compute.sync_target()?;
            }
        }
        Ok(StepStats { loss: out.loss, found_inf: out.found_inf, loss_scale: scale_used })
    }
}

fn argmax_row(q: &[f32]) -> usize {
    q.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(i, _)| i).unwrap_or(0)
}

impl<C: DqnCompute> Agent for DqnAgent<C> {
    fn act(&mut self, obs: &[f32], lanes: usize, rng: &mut Rng) -> Result<Vec<Action>> {
        // One batched forward for all lanes *before* the per-lane ε
        // draws: `qvalues` is RNG-free and side-effect-free, so at
        // `lanes == 1` the exploration stream is bit-identical to the
        // scalar path (which only ran the forward when exploiting).
        let q = self.compute.qvalues(obs, lanes)?;
        let na = q.len() / lanes;
        let mut out = Vec::with_capacity(lanes);
        for l in 0..lanes {
            self.env_steps += 1;
            if rng.uniform() < self.epsilon() {
                out.push(Action::Discrete(rng.below(self.cfg.n_actions)));
            } else {
                out.push(Action::Discrete(argmax_row(&q[l * na..(l + 1) * na])));
            }
        }
        Ok(out)
    }

    fn act_greedy(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<Action>> {
        let q = self.compute.qvalues(obs, lanes)?;
        let na = q.len() / lanes;
        Ok((0..lanes).map(|l| Action::Discrete(argmax_row(&q[l * na..(l + 1) * na]))).collect())
    }

    fn observe(
        &mut self,
        obs: &[f32],
        actions: &[Action],
        rewards: &[f32],
        next_obs: &[f32],
        dones: &[bool],
        rng: &mut Rng,
        stats: &mut Vec<StepStats>,
    ) -> Result<()> {
        let lanes = actions.len();
        let d = self.cfg.obs_dim();
        for l in 0..lanes {
            let a = actions[l].try_discrete()? as i32;
            self.replay.push(
                &obs[l * d..(l + 1) * d],
                StoredAction::Discrete(a),
                rewards[l],
                &next_obs[l * d..(l + 1) * d],
                dones[l],
            );
            self.obs_steps += 1;
            if self.replay.len() >= self.cfg.warmup
                && self.obs_steps % self.cfg.train_every as u64 == 0
            {
                stats.push(self.train_batch(rng)?);
            }
        }
        Ok(())
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn exec_policy(&self) -> Option<&ExecPolicy> {
        self.compute.exec_policy()
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![
            ("compute", self.compute.save_state()?),
            ("replay", self.replay.to_json()),
            ("scaler", self.scaler.to_json()),
            ("env_steps", Json::Num(self.env_steps as f64)),
            ("obs_steps", Json::Num(self.obs_steps as f64)),
            ("train_steps", Json::Num(self.train_steps as f64)),
        ]))
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.compute.restore_state(state.req("compute")?)?;
        self.replay = ReplayBuffer::from_json(state.req("replay")?)?;
        self.scaler = LossScaler::from_json(state.req("scaler")?)?;
        self.env_steps = state.req_u64("env_steps")?;
        self.obs_steps = state.req_u64("obs_steps")?;
        self.train_steps = state.req_u64("train_steps")?;
        Ok(())
    }
}
