//! DQN agent (paper Eq. 1): ε-greedy exploration, uniform replay,
//! periodic target-network sync, loss-scaling FSM.  All network math is
//! delegated to a [`DqnCompute`] backend — the CPU executor
//! ([`crate::exec::models::CpuDqn`], always available) or the PJRT
//! artifacts ([`super::pjrt`], `pjrt` feature).  Works for both MLP
//! (CartPole) and conv (mini-Breakout) combos.

use anyhow::Result;

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::quant::LossScaler;
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::compute::DqnCompute;
use super::replay::{ReplayBuffer, StoredAction};

/// DQN hyper-parameters (coordinator-side; the compute backend owns
/// lr/γ).
#[derive(Clone, Debug)]
pub struct DqnConfig {
    pub batch: usize,
    pub obs_shape: Vec<usize>,
    pub n_actions: usize,
    pub replay_capacity: usize,
    pub warmup: usize,
    pub train_every: usize,
    pub target_sync_every: u64,
    pub eps_start: f64,
    pub eps_end: f64,
    pub eps_decay_steps: f64,
}

impl DqnConfig {
    pub fn for_combo(batch: usize, obs_shape: Vec<usize>, n_actions: usize) -> Self {
        DqnConfig {
            batch,
            obs_shape,
            n_actions,
            replay_capacity: 20_000,
            warmup: 500,
            train_every: 1,
            target_sync_every: 200,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_steps: 4_000.0,
        }
    }

    pub(crate) fn obs_dim(&self) -> usize {
        self.obs_shape.iter().product()
    }
}

/// Coordination shell around a [`DqnCompute`] backend.
pub struct DqnAgent<C: DqnCompute> {
    cfg: DqnConfig,
    compute: C,
    replay: ReplayBuffer,
    scaler: LossScaler,
    env_steps: u64,
    train_steps: u64,
}

impl<C: DqnCompute> DqnAgent<C> {
    /// Assemble from a ready compute backend and an armed (or disabled)
    /// loss scaler.
    pub fn from_parts(cfg: DqnConfig, compute: C, scaler: LossScaler) -> Self {
        let replay = ReplayBuffer::new(cfg.replay_capacity, cfg.obs_dim());
        DqnAgent { cfg, compute, replay, scaler, env_steps: 0, train_steps: 0 }
    }

    fn epsilon(&self) -> f64 {
        let frac = (self.env_steps as f64 / self.cfg.eps_decay_steps).min(1.0);
        self.cfg.eps_start + (self.cfg.eps_end - self.cfg.eps_start) * frac
    }

    fn train_batch(&mut self, rng: &mut Rng) -> Result<StepStats> {
        let batch = self.replay.sample(self.cfg.batch, rng);
        let scale_used = self.scaler.scale();
        let out = self.compute.train(&batch, scale_used)?;
        let applied = self.scaler.update(out.found_inf);
        if applied {
            self.train_steps += 1;
            if self.train_steps % self.cfg.target_sync_every == 0 {
                self.compute.sync_target()?;
            }
        }
        Ok(StepStats { loss: out.loss, found_inf: out.found_inf, loss_scale: scale_used })
    }
}

impl<C: DqnCompute> Agent for DqnAgent<C> {
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action> {
        self.env_steps += 1;
        if rng.uniform() < self.epsilon() {
            return Ok(Action::Discrete(rng.below(self.cfg.n_actions)));
        }
        self.act_greedy(obs)
    }

    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action> {
        let q = self.compute.qvalues(obs)?;
        let best = q
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok(Action::Discrete(best))
    }

    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        rng: &mut Rng,
    ) -> Result<Option<StepStats>> {
        self.replay.push(
            obs,
            StoredAction::Discrete(action.discrete() as i32),
            reward,
            next_obs,
            done,
        );
        if self.replay.len() >= self.cfg.warmup && self.env_steps % self.cfg.train_every as u64 == 0
        {
            return self.train_batch(rng).map(Some);
        }
        Ok(None)
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn exec_policy(&self) -> Option<&ExecPolicy> {
        self.compute.exec_policy()
    }
}
