//! DQN agent (paper Eq. 1): ε-greedy exploration, uniform replay,
//! periodic target-network sync, train step via the `<combo>_<mode>_train`
//! artifact.  Works for both MLP (CartPole) and conv (mini-Breakout)
//! combos — the artifact signature is identical.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::envs::Action;
use crate::quant::LossScaler;
use crate::runtime::executor::{literal_f32, literal_i32, scalar_f32, scalar_of, to_vec_f32};
use crate::runtime::{Executor, Runtime};
use crate::util::Rng;

use super::agent::{Agent, StepStats};
use super::network::ParamSet;
use super::replay::{ReplayBuffer, StoredAction};

/// DQN hyper-parameters (coordinator-side; lr/γ are baked into the
/// artifact).
#[derive(Clone, Debug)]
pub struct DqnConfig {
    pub batch: usize,
    pub obs_shape: Vec<usize>,
    pub n_actions: usize,
    pub replay_capacity: usize,
    pub warmup: usize,
    pub train_every: usize,
    pub target_sync_every: u64,
    pub eps_start: f64,
    pub eps_end: f64,
    pub eps_decay_steps: f64,
}

impl DqnConfig {
    pub fn for_combo(batch: usize, obs_shape: Vec<usize>, n_actions: usize) -> Self {
        DqnConfig {
            batch,
            obs_shape,
            n_actions,
            replay_capacity: 20_000,
            warmup: 500,
            train_every: 1,
            target_sync_every: 200,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_steps: 4_000.0,
        }
    }

    fn obs_dim(&self) -> usize {
        self.obs_shape.iter().product()
    }
}

pub struct DqnAgent {
    cfg: DqnConfig,
    act_exe: Arc<Executor>,
    train_exe: Arc<Executor>,
    params: ParamSet,
    target: Vec<xla::Literal>,
    opt: Vec<xla::Literal>,
    replay: ReplayBuffer,
    scaler: LossScaler,
    env_steps: u64,
    train_steps: u64,
}

impl DqnAgent {
    /// Build from artifacts `<combo>_<mode>_{act,train}`.
    pub fn new(runtime: &mut Runtime, combo: &str, mode: &str, cfg: DqnConfig, seed: u64) -> Result<Self> {
        let act_exe = runtime.load(&format!("{combo}_{mode}_act"))?;
        let train_exe = runtime.load(&format!("{combo}_{mode}_train"))?;
        let shapes = train_exe.spec().param_shapes();
        if shapes.is_empty() {
            return Err(anyhow!("artifact {combo}_{mode}_train has no param_shapes meta"));
        }
        let mut rng = Rng::new(seed ^ 0xD09);
        let params = ParamSet::init(&shapes, &mut rng)?;
        let target = params.clone_literals();
        let opt = ParamSet::opt_state(&shapes)?;
        let scaled = train_exe
            .spec()
            .meta
            .get("scaled")
            .and_then(|b| b.as_bool())
            .unwrap_or(false);
        let scaler = if scaled { LossScaler::default() } else { LossScaler::disabled() };
        let replay = ReplayBuffer::new(cfg.replay_capacity, cfg.obs_dim());
        Ok(DqnAgent {
            cfg,
            act_exe,
            train_exe,
            params,
            target,
            opt,
            replay,
            scaler,
            env_steps: 0,
            train_steps: 0,
        })
    }

    fn epsilon(&self) -> f64 {
        let frac = (self.env_steps as f64 / self.cfg.eps_decay_steps).min(1.0);
        self.cfg.eps_start + (self.cfg.eps_end - self.cfg.eps_start) * frac
    }

    fn qvalues(&self, obs: &[f32]) -> Result<Vec<f32>> {
        let mut shape = vec![1usize];
        shape.extend(&self.cfg.obs_shape);
        let obs_lit = literal_f32(obs, &shape)?;
        let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
        inputs.push(&obs_lit);
        let outs = self.act_exe.run(&inputs)?;
        to_vec_f32(&outs[0])
    }

    fn train_batch(&mut self, rng: &mut Rng) -> Result<StepStats> {
        let bs = self.cfg.batch;
        let batch = self.replay.sample(bs, rng);
        let mut obs_shape = vec![bs];
        obs_shape.extend(&self.cfg.obs_shape);
        let scratch = [
            literal_f32(&batch.obs, &obs_shape)?,
            literal_i32(&batch.actions_i32, &[bs])?,
            literal_f32(&batch.rewards, &[bs])?,
            literal_f32(&batch.next_obs, &obs_shape)?,
            literal_f32(&batch.dones, &[bs])?,
            scalar_f32(self.scaler.scale())?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.params.tensors.iter().collect();
        inputs.extend(self.target.iter());
        inputs.extend(self.opt.iter());
        inputs.extend(scratch.iter());
        let mut outs = self.train_exe.run(&inputs)?;
        // outputs: params(k), opt(2k+1), loss, found_inf
        let k = self.params.len();
        let found_inf = scalar_of(&outs.pop().unwrap())? > 0.5;
        let loss = scalar_of(&outs.pop().unwrap())?;
        let opt = outs.split_off(k);
        self.params.replace(outs);
        self.opt = opt;
        let applied = self.scaler.update(found_inf);
        if applied {
            self.train_steps += 1;
            if self.train_steps % self.cfg.target_sync_every == 0 {
                self.target = self.params.clone_literals();
            }
        }
        Ok(StepStats { loss, found_inf, loss_scale: self.scaler.scale() })
    }
}

impl Agent for DqnAgent {
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action> {
        self.env_steps += 1;
        if rng.uniform() < self.epsilon() {
            return Ok(Action::Discrete(rng.below(self.cfg.n_actions)));
        }
        self.act_greedy(obs)
    }

    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action> {
        let q = self.qvalues(obs)?;
        let best = q
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok(Action::Discrete(best))
    }

    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        rng: &mut Rng,
    ) -> Result<Option<StepStats>> {
        self.replay.push(
            obs,
            StoredAction::Discrete(action.discrete() as i32),
            reward,
            next_obs,
            done,
        );
        if self.replay.len() >= self.cfg.warmup && self.env_steps % self.cfg.train_every as u64 == 0
        {
            return self.train_batch(rng).map(Some);
        }
        Ok(None)
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }
}
