//! Uniform experience replay (paper Fig 1's Experience Buffer).
//!
//! Stores flattened transitions in contiguous ring storage and samples
//! directly into the flat batch arrays the train artifacts take — no
//! per-sample allocation on the hot path.

use crate::util::json::{hex_f32s, parse_hex_f32s, Json, JsonError};
use crate::util::Rng;

/// Action payload stored per transition.
#[derive(Clone, Debug)]
pub enum StoredAction {
    Discrete(i32),
    Continuous(Vec<f32>),
}

/// Ring-buffer replay memory.
pub struct ReplayBuffer {
    capacity: usize,
    obs_dim: usize,
    obs: Vec<f32>,      // capacity × obs_dim
    next_obs: Vec<f32>, // capacity × obs_dim
    actions: Vec<StoredAction>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
    len: usize,
    head: usize,
}

/// One sampled batch, flat, artifact-ready.
#[derive(Default)]
pub struct Batch {
    pub obs: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub actions_i32: Vec<i32>,
    pub actions_f32: Vec<f32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<f32>,
    pub size: usize,
}

impl ReplayBuffer {
    pub fn new(capacity: usize, obs_dim: usize) -> Self {
        assert!(capacity > 0);
        ReplayBuffer {
            capacity,
            obs_dim,
            obs: vec![0.0; capacity * obs_dim],
            next_obs: vec![0.0; capacity * obs_dim],
            actions: Vec::with_capacity(capacity),
            rewards: vec![0.0; capacity],
            dones: vec![0.0; capacity],
            len: 0,
            head: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn push(
        &mut self,
        obs: &[f32],
        action: StoredAction,
        reward: f32,
        next_obs: &[f32],
        done: bool,
    ) {
        assert_eq!(obs.len(), self.obs_dim);
        assert_eq!(next_obs.len(), self.obs_dim);
        let i = self.head;
        self.obs[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(obs);
        self.next_obs[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(next_obs);
        if self.actions.len() <= i {
            self.actions.push(action);
        } else {
            self.actions[i] = action;
        }
        self.rewards[i] = reward;
        self.dones[i] = if done { 1.0 } else { 0.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
    }

    /// Serialize the populated ring bit-exactly (slots `0..len` are the
    /// populated ones regardless of wrap; `head` is the write cursor).
    /// Replay contents plus the restored trainer RNG reproduce every
    /// future sampled batch exactly.
    pub fn to_json(&self) -> Json {
        let n = self.len * self.obs_dim;
        let actions: Vec<Json> = self.actions[..self.len.min(self.actions.len())]
            .iter()
            .map(|a| match a {
                StoredAction::Discrete(d) => Json::Num(f64::from(*d)),
                StoredAction::Continuous(c) => Json::Str(hex_f32s(c)),
            })
            .collect();
        Json::obj(vec![
            ("capacity", Json::Num(self.capacity as f64)),
            ("obs_dim", Json::Num(self.obs_dim as f64)),
            ("len", Json::Num(self.len as f64)),
            ("head", Json::Num(self.head as f64)),
            ("obs", Json::Str(hex_f32s(&self.obs[..n]))),
            ("next_obs", Json::Str(hex_f32s(&self.next_obs[..n]))),
            ("actions", Json::Arr(actions)),
            ("rewards", Json::Str(hex_f32s(&self.rewards[..self.len]))),
            ("dones", Json::Str(hex_f32s(&self.dones[..self.len]))),
        ])
    }

    /// Rebuild a buffer from a [`ReplayBuffer::to_json`] snapshot.
    pub fn from_json(v: &Json) -> Result<ReplayBuffer, JsonError> {
        let bad = |msg: &str| JsonError { msg: msg.into(), pos: 0 };
        let capacity = v.req_u64("capacity")? as usize;
        let obs_dim = v.req_u64("obs_dim")? as usize;
        let len = v.req_u64("len")? as usize;
        let head = v.req_u64("head")? as usize;
        if capacity == 0 || len > capacity || head >= capacity.max(1) {
            return Err(bad("replay: inconsistent ring geometry"));
        }
        let mut rb = ReplayBuffer::new(capacity, obs_dim);
        let obs = parse_hex_f32s(v.req_str("obs")?)?;
        let next_obs = parse_hex_f32s(v.req_str("next_obs")?)?;
        let rewards = parse_hex_f32s(v.req_str("rewards")?)?;
        let dones = parse_hex_f32s(v.req_str("dones")?)?;
        let actions = v.req_arr("actions")?;
        if obs.len() != len * obs_dim
            || next_obs.len() != len * obs_dim
            || rewards.len() != len
            || dones.len() != len
            || actions.len() != len
        {
            return Err(bad("replay: payload lengths disagree with len"));
        }
        rb.obs[..obs.len()].copy_from_slice(&obs);
        rb.next_obs[..next_obs.len()].copy_from_slice(&next_obs);
        rb.rewards[..len].copy_from_slice(&rewards);
        rb.dones[..len].copy_from_slice(&dones);
        for a in actions {
            rb.actions.push(match a {
                Json::Num(d) => StoredAction::Discrete(*d as i32),
                Json::Str(s) => StoredAction::Continuous(parse_hex_f32s(s)?),
                _ => return Err(bad("replay: bad action entry")),
            });
        }
        rb.len = len;
        rb.head = head;
        Ok(rb)
    }

    /// Uniform sample of `bs` transitions (with replacement, as usual for
    /// DQN-style replay).
    pub fn sample(&self, bs: usize, rng: &mut Rng) -> Batch {
        let mut b = Batch::default();
        self.sample_into(bs, rng, &mut b);
        b
    }

    /// [`sample`](Self::sample) into a caller-owned batch, reusing its
    /// capacity — the hot collection loop samples thousands of batches
    /// and this keeps them allocation-free after the first.  Identical
    /// RNG consumption and contents (asserted in the module tests).
    pub fn sample_into(&self, bs: usize, rng: &mut Rng, b: &mut Batch) {
        assert!(self.len > 0, "sampling from empty replay buffer");
        b.obs.clear();
        b.next_obs.clear();
        b.actions_i32.clear();
        b.actions_f32.clear();
        b.rewards.clear();
        b.dones.clear();
        b.size = bs;
        for _ in 0..bs {
            let i = rng.below(self.len);
            b.obs.extend_from_slice(&self.obs[i * self.obs_dim..(i + 1) * self.obs_dim]);
            b.next_obs
                .extend_from_slice(&self.next_obs[i * self.obs_dim..(i + 1) * self.obs_dim]);
            match &self.actions[i] {
                StoredAction::Discrete(a) => b.actions_i32.push(*a),
                StoredAction::Continuous(a) => b.actions_f32.extend_from_slice(a),
            }
            b.rewards.push(self.rewards[i]);
            b.dones.push(self.dones[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps() {
        let mut rb = ReplayBuffer::new(4, 2);
        for k in 0..10 {
            rb.push(
                &[k as f32, 0.0],
                StoredAction::Discrete(k),
                k as f32,
                &[k as f32 + 1.0, 0.0],
                false,
            );
        }
        assert_eq!(rb.len(), 4);
        // the ring now holds transitions 6..=9
        let mut rng = Rng::new(1);
        let b = rb.sample(64, &mut rng);
        assert!(b.rewards.iter().all(|&r| (6.0..=9.0).contains(&r)));
    }

    #[test]
    fn batch_layout() {
        let mut rb = ReplayBuffer::new(8, 3);
        rb.push(&[1.0, 2.0, 3.0], StoredAction::Continuous(vec![0.5, -0.5]), 1.0, &[4.0, 5.0, 6.0], true);
        let mut rng = Rng::new(2);
        let b = rb.sample(2, &mut rng);
        assert_eq!(b.obs.len(), 6);
        assert_eq!(b.actions_f32.len(), 4);
        assert_eq!(b.dones, vec![1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "empty replay")]
    fn sample_empty_panics() {
        let rb = ReplayBuffer::new(4, 1);
        rb.sample(1, &mut Rng::new(0));
    }

    #[test]
    fn json_round_trip_reproduces_future_samples_and_pushes() {
        let mut rb = ReplayBuffer::new(4, 2);
        for k in 0..6 {
            // wrap the ring so head != len
            rb.push(
                &[k as f32, -(k as f32)],
                StoredAction::Continuous(vec![0.5 * k as f32]),
                k as f32,
                &[k as f32 + 1.0, 0.0],
                k % 2 == 0,
            );
        }
        let mut restored = ReplayBuffer::from_json(&rb.to_json()).unwrap();
        assert_eq!(restored.len(), rb.len());
        assert_eq!(restored.head, rb.head);
        // Same future pushes + identically seeded sampling must bit-match.
        for b in [&mut rb, &mut restored] {
            b.push(&[9.0, 9.0], StoredAction::Continuous(vec![1.0]), 9.0, &[10.0, 10.0], false);
        }
        let (mut ra, mut rbx) = (Rng::new(42), Rng::new(42));
        let a = rb.sample(16, &mut ra);
        let b = restored.sample(16, &mut rbx);
        assert_eq!(a.obs, b.obs);
        assert_eq!(a.actions_f32, b.actions_f32);
        assert_eq!(a.rewards, b.rewards);
        assert_eq!(a.dones, b.dones);
    }

    #[test]
    fn sample_into_reuses_capacity_without_behavior_change() {
        let mut rb = ReplayBuffer::new(16, 2);
        for k in 0..12 {
            rb.push(
                &[k as f32, -(k as f32)],
                StoredAction::Discrete(k),
                k as f32,
                &[k as f32 + 1.0, 0.0],
                k % 3 == 0,
            );
        }
        // Reused batch (second fill) must bit-match a fresh `sample`
        // drawn with an identically-seeded rng.
        let mut reused = Batch::default();
        let mut rng_a = Rng::new(7);
        rb.sample_into(8, &mut rng_a, &mut reused); // warm the capacity
        rb.sample_into(8, &mut rng_a, &mut reused);
        let mut rng_b = Rng::new(7);
        let _ = rb.sample(8, &mut rng_b);
        let fresh = rb.sample(8, &mut rng_b);
        assert_eq!(reused.obs, fresh.obs);
        assert_eq!(reused.next_obs, fresh.next_obs);
        assert_eq!(reused.actions_i32, fresh.actions_i32);
        assert_eq!(reused.actions_f32, fresh.actions_f32);
        assert_eq!(reused.rewards, fresh.rewards);
        assert_eq!(reused.dones, fresh.dones);
        assert_eq!(reused.size, fresh.size);
    }
}
