//! Backend-agnostic per-algorithm compute interfaces.
//!
//! The agents in this module's siblings own all *coordination* —
//! exploration schedules, replay/rollout buffers, target-sync cadence,
//! the loss-scaling FSM — and delegate all *network math* to one of
//! these traits.  Two families implement them:
//!
//! * the pure-Rust CPU executor ([`crate::exec::models`]), always
//!   compiled, with the quantization policy live per layer;
//! * the PJRT artifact executors ([`super::pjrt`], `pjrt` feature),
//!   where the same math is a lowered XLA computation.
//!
//! A compute impl owns its parameters and optimizer state; `train`
//! receives the batch plus the FSM's current loss scale and reports the
//! (unscaled) loss and the overflow flag the FSM consumes.

use anyhow::{bail, Result};

use crate::exec::ExecPolicy;
use crate::util::json::Json;

use super::replay::Batch;
use super::rollout::RolloutBatch;

/// One train step's compute-level outcome.
#[derive(Clone, Copy, Debug)]
pub struct TrainOut {
    /// Unscaled loss value (the primary loss for multi-loss algorithms).
    pub loss: f32,
    /// Scaled-gradient overflow was detected and the update skipped.
    pub found_inf: bool,
}

/// Introspection shared by every compute backend.
pub trait ComputeBackend {
    /// The precision routing this backend executes under, when it is
    /// explicit (the CPU executor).  PJRT artifacts keep their formats
    /// baked into the lowered computation and return `None`.
    fn exec_policy(&self) -> Option<&ExecPolicy> {
        None
    }

    /// Serialize all learnable state — weights, masters, optimizer
    /// moments — bit-exactly for checkpoints.  Backends that cannot
    /// export their parameters (PJRT artifacts) keep the default error.
    fn save_state(&self) -> Result<Json> {
        bail!("this compute backend does not support checkpointing")
    }

    /// Restore state saved by [`ComputeBackend::save_state`] into a
    /// structurally identical backend (same combo + policy).
    fn restore_state(&mut self, _state: &Json) -> Result<()> {
        bail!("this compute backend does not support checkpointing")
    }
}

/// DQN: online/target Q-networks, one train step per sampled batch.
///
/// Inference methods are N-wide: `obs` stacks `lanes` observations
/// lane-major (`lanes × obs_dim`) and outputs come back lane-major too,
/// so the actor fleet costs one GEMM per layer.  Rows are independent
/// in every kernel, so `lanes == 1` is bit-identical to the old scalar
/// signatures.
pub trait DqnCompute: ComputeBackend {
    /// Q-values for `lanes` stacked observations (`lanes × n_actions`).
    fn qvalues(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<f32>>;
    fn train(&mut self, batch: &Batch, loss_scale: f32) -> Result<TrainOut>;
    /// Hard-sync the target network from the online one (agent-scheduled).
    fn sync_target(&mut self) -> Result<()>;
}

/// A2C: Gaussian policy + value net over GAE rollouts.
pub trait A2cCompute: ComputeBackend {
    /// `(means lanes × act_dim, log_std act_dim, values lanes)` for
    /// `lanes` stacked observations (log_std is state-independent).
    fn policy(&mut self, obs: &[f32], lanes: usize) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>;
    fn train(&mut self, batch: &RolloutBatch, loss_scale: f32) -> Result<TrainOut>;
}

/// DDPG: deterministic actor + Q critic with soft-updated targets.
pub trait DdpgCompute: ComputeBackend {
    /// Deterministic actions for `lanes` stacked observations
    /// (`lanes × act_dim`).
    fn action(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<f32>>;
    fn train(&mut self, batch: &Batch, loss_scale: f32) -> Result<TrainOut>;
}

/// PPO: discrete actor-critic, clipped-surrogate epochs over one rollout.
pub trait PpoCompute: ComputeBackend {
    /// `(logits lanes × n_actions, values lanes)` for `lanes` stacked
    /// observations.
    fn policy(&mut self, obs: &[f32], lanes: usize) -> Result<(Vec<f32>, Vec<f32>)>;
    fn train(&mut self, batch: &RolloutBatch, loss_scale: f32) -> Result<TrainOut>;
}
