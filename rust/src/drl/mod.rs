//! DRL agents (paper §II-A / Fig 1): the Inference → Environment Step →
//! Train loop, with all coordination (exploration, replay, GAE,
//! target-network schedule, loss-scaling FSM) here and all network
//! compute behind the per-algorithm [`compute`] traits.
//!
//! Two compute families implement those traits: the always-available
//! pure-Rust CPU executor ([`crate::exec::models`]) and the PJRT
//! artifact executors ([`pjrt`], gated behind the **`pjrt`** feature
//! together with the parameter marshaling in [`network`]).

pub mod a2c;
pub mod agent;
pub mod compute;
pub mod ddpg;
pub mod dqn;
#[cfg(feature = "pjrt")]
pub mod network;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod ppo;
pub mod replay;
pub mod rollout;

pub use agent::{Agent, StepStats};
pub use compute::{A2cCompute, ComputeBackend, DdpgCompute, DqnCompute, PpoCompute, TrainOut};
#[cfg(feature = "pjrt")]
pub use network::ParamSet;
pub use replay::ReplayBuffer;
pub use rollout::RolloutBuffer;
