//! DRL agents (paper §II-A / Fig 1): the Inference → Environment Step →
//! Train loop, with all network compute executed through the PJRT
//! artifacts (L2/L1) and all coordination (exploration, replay, GAE,
//! target-network schedule, loss-scaling FSM) here at L3.

pub mod a2c;
pub mod agent;
pub mod ddpg;
pub mod dqn;
pub mod network;
pub mod ppo;
pub mod replay;
pub mod rollout;

pub use agent::{Agent, StepStats};
pub use network::ParamSet;
pub use replay::ReplayBuffer;
pub use rollout::RolloutBuffer;
