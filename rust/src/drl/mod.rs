//! DRL agents (paper §II-A / Fig 1): the Inference → Environment Step →
//! Train loop, with all network compute executed through the PJRT
//! artifacts (L2/L1) and all coordination (exploration, replay, GAE,
//! target-network schedule, loss-scaling FSM) here at L3.
//!
//! The agent implementations and parameter marshaling execute PJRT
//! artifacts, so they are gated behind the **`pjrt`** feature; the pure
//! coordination substrates ([`agent`] trait, [`replay`], [`rollout`])
//! are always available.

#[cfg(feature = "pjrt")]
pub mod a2c;
pub mod agent;
#[cfg(feature = "pjrt")]
pub mod ddpg;
#[cfg(feature = "pjrt")]
pub mod dqn;
#[cfg(feature = "pjrt")]
pub mod network;
#[cfg(feature = "pjrt")]
pub mod ppo;
pub mod replay;
pub mod rollout;

pub use agent::{Agent, StepStats};
#[cfg(feature = "pjrt")]
pub use network::ParamSet;
pub use replay::ReplayBuffer;
pub use rollout::RolloutBuffer;
