//! Common agent interface driven by the coordinator's env loop.

use anyhow::Result;

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::util::Rng;

/// Telemetry from one executed train step.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f32,
    pub found_inf: bool,
    /// Loss scale *fed to* this step (pre-FSM-update), so consecutive
    /// stats expose every FSM transition including the first backoff.
    pub loss_scale: f32,
}

/// A DRL agent: picks actions and learns from transitions.  All network
/// math goes through a compute backend ([`super::compute`]) — the CPU
/// executor or the PJRT artifacts; the implementations only coordinate.
pub trait Agent {
    /// Select an action for `obs` (exploration noise included).
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action>;

    /// Record a transition; returns train-step stats whenever the agent
    /// decided to run one (buffer warm, rollout full, ...).
    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        rng: &mut Rng,
    ) -> Result<Option<StepStats>>;

    /// Greedy action (evaluation, no exploration).
    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action>;

    /// Number of optimizer steps taken so far.
    fn train_steps(&self) -> u64;

    /// The explicit precision routing of the backing compute, when it
    /// has one (the CPU exec backend).  `None` for backends whose
    /// formats are baked into lowered artifacts (PJRT).
    fn exec_policy(&self) -> Option<&ExecPolicy> {
        None
    }
}
