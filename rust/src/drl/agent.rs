//! Common agent interface driven by the coordinator's env loop.

use anyhow::Result;

use crate::envs::Action;
use crate::util::Rng;

/// Telemetry from one executed train step.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f32,
    pub found_inf: bool,
    pub loss_scale: f32,
}

/// A DRL agent: picks actions and learns from transitions.  All network
/// math goes through PJRT artifacts; the implementations only coordinate.
pub trait Agent {
    /// Select an action for `obs` (exploration noise included).
    fn act(&mut self, obs: &[f32], rng: &mut Rng) -> Result<Action>;

    /// Record a transition; returns train-step stats whenever the agent
    /// decided to run one (buffer warm, rollout full, ...).
    fn observe(
        &mut self,
        obs: &[f32],
        action: &Action,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        rng: &mut Rng,
    ) -> Result<Option<StepStats>>;

    /// Greedy action (evaluation, no exploration).
    fn act_greedy(&mut self, obs: &[f32]) -> Result<Action>;

    /// Number of optimizer steps taken so far.
    fn train_steps(&self) -> u64;
}
