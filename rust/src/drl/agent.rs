//! Common agent interface driven by the coordinator's env loop.

use anyhow::{bail, Result};

use crate::envs::Action;
use crate::exec::ExecPolicy;
use crate::util::json::Json;
use crate::util::Rng;

/// Telemetry from one executed train step.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f32,
    pub found_inf: bool,
    /// Loss scale *fed to* this step (pre-FSM-update), so consecutive
    /// stats expose every FSM transition including the first backoff.
    pub loss_scale: f32,
}

/// A DRL agent: picks actions and learns from transitions.  All network
/// math goes through a compute backend ([`super::compute`]) — the CPU
/// executor or the PJRT artifacts; the implementations only coordinate.
///
/// The interface is N-wide: `obs` stacks `lanes` observations lane-major
/// (`lanes × obs_dim`) so actor inference issues *one* GEMM per layer
/// for the whole fleet.  At `lanes == 1` every implementation is
/// bit-identical to the scalar path it replaced: the batched forward
/// degenerates to the same row math, and per-lane RNG draws happen in
/// the same order (asserted in `tests/train.rs`).
pub trait Agent {
    /// Select one action per lane for `obs` (`lanes × obs_dim`,
    /// exploration noise included).
    fn act(&mut self, obs: &[f32], lanes: usize, rng: &mut Rng) -> Result<Vec<Action>>;

    /// Record one transition per lane; appends train-step stats to
    /// `stats` whenever a push triggered a train step (buffer warm,
    /// rollout full, ...) — possibly several per call at `lanes > 1`.
    #[allow(clippy::too_many_arguments)]
    fn observe(
        &mut self,
        obs: &[f32],
        actions: &[Action],
        rewards: &[f32],
        next_obs: &[f32],
        dones: &[bool],
        rng: &mut Rng,
        stats: &mut Vec<StepStats>,
    ) -> Result<()>;

    /// Greedy actions (evaluation, no exploration), one per lane.
    fn act_greedy(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<Action>>;

    /// Number of optimizer steps taken so far.
    fn train_steps(&self) -> u64;

    /// The explicit precision routing of the backing compute, when it
    /// has one (the CPU exec backend).  `None` for backends whose
    /// formats are baked into lowered artifacts (PJRT).
    fn exec_policy(&self) -> Option<&ExecPolicy> {
        None
    }

    /// Bit-exact snapshot of the agent's full learning state — compute
    /// backend (weights, masters, optimizer moments), experience
    /// buffers, loss-scale FSM and cadence counters.  Must be taken at
    /// a round boundary (after `observe`, before the next `act`).
    /// Defaults to an error for agents whose backend cannot export its
    /// parameters (PJRT artifacts).
    fn save_state(&self) -> Result<Json> {
        bail!("this agent does not support checkpointing")
    }

    /// Restore an [`Agent::save_state`] snapshot into a structurally
    /// identical agent (same combo, exec policy and config).
    fn restore_state(&mut self, _state: &Json) -> Result<()> {
        bail!("this agent does not support checkpointing")
    }
}
