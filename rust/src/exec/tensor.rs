//! Dense CPU tensors for the pure-Rust execution backend.
//!
//! A [`Tensor`] is a row-major `Vec<f32>` plus a shape.  The backend only
//! needs rank-1/2 algebra (batched activations are `(batch, features)`
//! matrices; conv layers run through their im2col GEMM shape, exactly the
//! taxonomy the partitioner's CDFG uses), so the op set is deliberately
//! small: three GEMM variants, bias/row reductions and in-place format
//! rounding via [`crate::quant::formats`].
//!
//! All accumulation is f32; the coordinated formats (BF16/FP16) are
//! applied *between* ops by [`Tensor::round_to`], mirroring how the AIE /
//! PL datapaths store operands in the narrow format but accumulate wide.

use crate::hw::Format;
use crate::quant::formats::round_to;

/// Row-major dense tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let elems: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; elems] }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        let elems: usize = shape.iter().product();
        assert_eq!(data.len(), elems, "data/shape mismatch: {} vs {:?}", data.len(), shape);
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// First dimension (batch size for activation matrices).
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Trailing element count per row (features).
    pub fn cols(&self) -> usize {
        self.data.len() / self.shape[0].max(1)
    }

    /// In-place round of every element into `fmt` (identity for FP32).
    pub fn round_to(&mut self, fmt: Format) {
        if fmt == Format::Fp32 {
            return;
        }
        for x in self.data.iter_mut() {
            *x = round_to(*x, fmt);
        }
    }

    /// True when any element is NaN/±inf — the `found_inf` probe the
    /// loss-scaling FSM consumes (FP16 rounding overflows to ±inf).
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    /// `(m,k) · (k,n)` GEMM, f32 accumulation, ikj loop order.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.shape[0], self.cols());
        assert_eq!(k, b.shape[0], "matmul inner dims: {k} vs {}", b.shape[0]);
        let n = b.cols();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate() {
                let brow = &b.data[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * bv;
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// `selfᵀ · b`: self is `(m,k)`, b is `(m,n)`, result `(k,n)` —
    /// the dw GEMM (`xᵀ · dz`) of a dense layer's backward pass.
    pub fn matmul_tn(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.shape[0], self.cols());
        assert_eq!(m, b.shape[0], "matmul_tn outer dims: {m} vs {}", b.shape[0]);
        let n = b.cols();
        let mut out = vec![0.0f32; k * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let brow = &b.data[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate() {
                let orow = &mut out[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * bv;
                }
            }
        }
        Tensor { shape: vec![k, n], data: out }
    }

    /// `self · bᵀ`: self is `(m,k)`, b is `(n,k)`, result `(m,n)` —
    /// the dx GEMM (`dz · wᵀ`) of a dense layer's backward pass.
    pub fn matmul_nt(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.shape[0], self.cols());
        let n = b.shape[0];
        assert_eq!(k, b.cols(), "matmul_nt inner dims: {k} vs {}", b.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&a, &bv) in arow.iter().zip(brow.iter()) {
                    acc += a * bv;
                }
                out[i * n + j] = acc;
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// Add `bias` (len = cols) to every row.
    pub fn add_bias(&mut self, bias: &[f32]) {
        let n = self.cols();
        assert_eq!(bias.len(), n, "bias length {} vs cols {n}", bias.len());
        for row in self.data.chunks_mut(n) {
            for (x, &b) in row.iter_mut().zip(bias.iter()) {
                *x += b;
            }
        }
    }

    /// Column sums (the db reduction of a dense layer's backward pass).
    pub fn col_sums(&self) -> Vec<f32> {
        let n = self.cols();
        let mut out = vec![0.0f32; n];
        for row in self.data.chunks(n) {
            for (o, &x) in out.iter_mut().zip(row.iter()) {
                *o += x;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: &[f32], shape: &[usize]) -> Tensor {
        Tensor::from_vec(data.to_vec(), shape)
    }

    #[test]
    fn matmul_small() {
        // (2,3)·(3,2)
        let a = t(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let b = t(&[7.0, 8.0, 9.0, 10.0, 11.0, 12.0], &[3, 2]);
        let c = a.matmul(&b);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_variants_agree_with_explicit_transpose() {
        let a = t(&[1.0, -2.0, 0.5, 3.0, 4.0, -1.0], &[2, 3]);
        let b = t(&[2.0, 1.0, 0.0, -1.0, 1.5, 2.5], &[2, 3]);
        // aᵀ·b via matmul_tn == transpose(a)·b
        let at = t(&[1.0, 3.0, -2.0, 4.0, 0.5, -1.0], &[3, 2]);
        assert_eq!(a.matmul_tn(&b).data, at.matmul(&b).data);
        // a·bᵀ via matmul_nt == a·transpose(b)
        let bt = t(&[2.0, -1.0, 1.0, 1.5, 0.0, 2.5], &[3, 2]);
        assert_eq!(a.matmul_nt(&b).data, a.matmul(&bt).data);
    }

    #[test]
    fn bias_and_col_sums() {
        let mut x = t(&[1.0, 2.0, 3.0, 4.0], &[2, 2]);
        x.add_bias(&[10.0, 20.0]);
        assert_eq!(x.data, vec![11.0, 22.0, 13.0, 24.0]);
        assert_eq!(x.col_sums(), vec![24.0, 46.0]);
    }

    #[test]
    fn rounding_and_overflow_probe() {
        let mut x = t(&[1.0, 1e6, -3.0e-8], &[3]);
        assert!(!x.has_non_finite());
        x.round_to(Format::Fp16);
        assert!(x.data[1].is_infinite(), "fp16 overflow must surface as inf");
        assert!(x.has_non_finite());
        let mut y = t(&[1.0, 2.0], &[2]);
        y.round_to(Format::Fp32);
        assert_eq!(y.data, vec![1.0, 2.0]);
    }
}
