//! Dense CPU tensors for the pure-Rust execution backend.
//!
//! A [`Tensor`] is a row-major `Vec<f32>` plus a shape.  The backend only
//! needs rank-1/2 algebra (batched activations are `(batch, features)`
//! matrices; conv layers run through their im2col GEMM shape, exactly the
//! taxonomy the partitioner's CDFG uses), so the op set is deliberately
//! small: three GEMM variants, bias/row reductions and in-place format
//! rounding via [`crate::quant::formats`].
//!
//! All accumulation is f32; the coordinated formats (BF16/FP16) are
//! applied *between* ops by [`Tensor::round_to`] (the vectorized
//! [`round_slice`] fast path), mirroring how the AIE / PL datapaths store
//! operands in the narrow format but accumulate wide.
//!
//! ## Fast kernels, bit-exact by construction
//!
//! Each GEMM variant ships in two implementations:
//!
//! * `matmul{,_tn,_nt}_naive` — the original triple loops, kept as the
//!   reference the kernel-equivalence suite (`tests/kernels.rs`) pins
//!   everything else against;
//! * `matmul{,_tn,_nt}` / `*_with(pool)` — cache-blocked kernels: the
//!   right operand is packed once into `NR`-wide panels, the left
//!   operand into `MR`-row groups per (row-block × k-block), and an
//!   `MR×NR` register-accumulator micro-kernel walks the reduction.
//!   Output row-blocks are independent, so they fan out over a
//!   [`Pool`] (`APDRL_THREADS`).
//!
//! The blocked kernels keep the **per-output-element f32 accumulation
//! order identical to the naive references**: reduction blocks are
//! visited in ascending order and every partial sum round-trips through
//! f32 exactly, so `blocked == naive` bit-for-bit — at any thread
//! count, because each output row is owned by exactly one task.  That
//! is what lets the mixed-precision training loop (loss-scale FSM,
//! reward trajectories) stay bit-identical when `APDRL_THREADS` changes.

use crate::hw::Format;
use crate::obs::trace;
use crate::quant::formats::round_slice;

use super::pool::Pool;

/// Micro-kernel rows (left-operand register tile height).
const MR: usize = 4;
/// Micro-kernel lanes (packed right-operand panel width).
const NR: usize = 8;
/// Output rows per parallel task / cache block.
const MC: usize = 32;
/// Reduction-dimension block (keeps the packed A panel L1/L2-resident).
const KC: usize = 256;
/// Below this many multiply-accumulates a GEMM stays sequential — the
/// pool's wake/join latency would dominate (results are identical
/// either way; this is purely a latency knob).
const PAR_MIN_MACS: usize = 65_536;

/// Row-major dense tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let elems: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; elems] }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        let elems: usize = shape.iter().product();
        assert_eq!(data.len(), elems, "data/shape mismatch: {} vs {:?}", data.len(), shape);
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// First dimension (batch size for activation matrices).
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Trailing element count per row: the product of `shape[1..]`.
    ///
    /// Defined from the *shape*, not `data.len() / rows`, so empty
    /// tensors keep their true row width (`shape == [0, n]` → `n`) —
    /// zero-sized GEMM operands would otherwise lose their inner
    /// dimension and fail the conformance asserts.  Rank-1 tensors are
    /// column vectors (`cols() == 1`); rank-0 tensors are rejected —
    /// every executor tensor carries at least one dimension.
    pub fn cols(&self) -> usize {
        assert!(!self.shape.is_empty(), "cols() on a rank-0 tensor");
        self.shape[1..].iter().product()
    }

    /// In-place round of every element into `fmt` (identity for FP32),
    /// through the vectorized [`round_slice`] fast path.
    pub fn round_to(&mut self, fmt: Format) {
        // Identity formats skip the span: only real f16/bf16 rounding
        // work should calibrate the `round_slice` cost entry.
        let _span = match fmt {
            Format::Fp16 | Format::Bf16 => {
                trace::span(trace::Kernel::RoundSlice, [self.data.len(), 0, 0], 1)
            }
            _ => None,
        };
        round_slice(&mut self.data, fmt);
    }

    /// True when any element is NaN/±inf — the `found_inf` probe the
    /// loss-scaling FSM consumes (FP16 rounding overflows to ±inf).
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    // ------------------------------------------------ naive references --

    /// `(m,k) · (k,n)` GEMM, f32 accumulation, ikj loop order — the
    /// reference implementation the blocked kernels are bit-pinned to.
    pub fn matmul_naive(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.shape[0], self.cols());
        assert_eq!(k, b.shape[0], "matmul inner dims: {k} vs {}", b.shape[0]);
        let n = b.cols();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate() {
                let brow = &b.data[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * bv;
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// `selfᵀ · b` reference: self is `(m,k)`, b is `(m,n)`, result
    /// `(k,n)` — the dw GEMM (`xᵀ · dz`) of a dense backward pass.
    pub fn matmul_tn_naive(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.shape[0], self.cols());
        assert_eq!(m, b.shape[0], "matmul_tn outer dims: {m} vs {}", b.shape[0]);
        let n = b.cols();
        let mut out = vec![0.0f32; k * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let brow = &b.data[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate() {
                let orow = &mut out[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * bv;
                }
            }
        }
        Tensor { shape: vec![k, n], data: out }
    }

    /// `self · bᵀ` reference: self is `(m,k)`, b is `(n,k)`, result
    /// `(m,n)` — the dx GEMM (`dz · wᵀ`) of a dense backward pass.
    pub fn matmul_nt_naive(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.shape[0], self.cols());
        let n = b.shape[0];
        assert_eq!(k, b.cols(), "matmul_nt inner dims: {k} vs {}", b.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&a, &bv) in arow.iter().zip(brow.iter()) {
                    acc += a * bv;
                }
                out[i * n + j] = acc;
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    // ------------------------------------------------- blocked kernels --

    /// `(m,k) · (k,n)` GEMM — blocked/packed, parallel on `pool`,
    /// bit-identical to [`Tensor::matmul_naive`].
    pub fn matmul_with(&self, b: &Tensor, pool: &Pool) -> Tensor {
        let (m, k) = (self.shape[0], self.cols());
        assert_eq!(k, b.shape[0], "matmul inner dims: {k} vs {}", b.shape[0]);
        let n = b.cols();
        let _span = trace::span(trace::Kernel::GemmNn, [m, k, n], pool.threads());
        let bpack = pack_b_rows(&b.data, k, n);
        let data = gemm(&self.data, k, false, &bpack, m, n, k, pool);
        Tensor { shape: vec![m, n], data }
    }

    /// `selfᵀ · b` — blocked/packed, bit-identical to
    /// [`Tensor::matmul_tn_naive`].  The reduction runs over this
    /// tensor's rows, so the packed left panel reads contiguous
    /// `MR`-chunks of each row (no strided gather).
    pub fn matmul_tn_with(&self, b: &Tensor, pool: &Pool) -> Tensor {
        let (m, k) = (self.shape[0], self.cols());
        assert_eq!(m, b.shape[0], "matmul_tn outer dims: {m} vs {}", b.shape[0]);
        let n = b.cols();
        let _span = trace::span(trace::Kernel::GemmTn, [k, m, n], pool.threads());
        let bpack = pack_b_rows(&b.data, m, n);
        let data = gemm(&self.data, k, true, &bpack, k, n, m, pool);
        Tensor { shape: vec![k, n], data }
    }

    /// `self · bᵀ` — blocked, with `b` packed *transposed* so the
    /// micro-kernel streams contiguous panels; bit-identical to
    /// [`Tensor::matmul_nt_naive`] (same per-element term order; the
    /// partial sums round-trip through f32 exactly).
    pub fn matmul_nt_with(&self, b: &Tensor, pool: &Pool) -> Tensor {
        let (m, k) = (self.shape[0], self.cols());
        let n = b.shape[0];
        assert_eq!(k, b.cols(), "matmul_nt inner dims: {k} vs {}", b.cols());
        let _span = trace::span(trace::Kernel::GemmNt, [m, k, n], pool.threads());
        let bpack = pack_b_cols(&b.data, k, n);
        let data = gemm(&self.data, k, false, &bpack, m, n, k, pool);
        Tensor { shape: vec![m, n], data }
    }

    /// [`Tensor::matmul_with`] on the process-wide pool.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        self.matmul_with(b, &Pool::global())
    }

    /// [`Tensor::matmul_tn_with`] on the process-wide pool.
    pub fn matmul_tn(&self, b: &Tensor) -> Tensor {
        self.matmul_tn_with(b, &Pool::global())
    }

    /// [`Tensor::matmul_nt_with`] on the process-wide pool.
    pub fn matmul_nt(&self, b: &Tensor) -> Tensor {
        self.matmul_nt_with(b, &Pool::global())
    }

    /// Add `bias` (len = cols) to every row.
    pub fn add_bias(&mut self, bias: &[f32]) {
        let n = self.cols();
        assert_eq!(bias.len(), n, "bias length {} vs cols {n}", bias.len());
        for row in self.data.chunks_mut(n) {
            for (x, &b) in row.iter_mut().zip(bias.iter()) {
                *x += b;
            }
        }
    }

    /// Column sums (the db reduction of a dense layer's backward pass).
    pub fn col_sums(&self) -> Vec<f32> {
        let n = self.cols();
        let mut out = vec![0.0f32; n];
        for row in self.data.chunks(n) {
            for (o, &x) in out.iter_mut().zip(row.iter()) {
                *o += x;
            }
        }
        out
    }
}

// ------------------------------------------------------------------------
// Blocked GEMM internals.  The logical problem is always
// `out[row][j] = Σ_p A(row, p) · Bp(p, j)` with `row < mout`,
// `j < nout`, `p < red`; the three public variants differ only in how
// `A(row, p)` maps onto this tensor's storage (`atrans`) and how `Bp`
// was packed (row-major vs transposed source).

/// Pack row-major `b` (`red × nout`) into `NR`-wide strip-major panels:
/// `out[s·red·NR + p·NR + l] = b[p][s·NR + l]`, zero-padding the last
/// strip's missing lanes (padded lanes are never stored back).
fn pack_b_rows(b: &[f32], red: usize, nout: usize) -> Vec<f32> {
    let nstrips = nout.div_ceil(NR);
    let mut out = vec![0.0f32; nstrips * red * NR];
    for s in 0..nstrips {
        let j0 = s * NR;
        let w = NR.min(nout - j0);
        let base = s * red * NR;
        for p in 0..red {
            let src = &b[p * nout + j0..p * nout + j0 + w];
            out[base + p * NR..base + p * NR + w].copy_from_slice(src);
        }
    }
    out
}

/// Pack row-major `b` (`nout × red`) *transposed* into the same panel
/// layout: `out[s·red·NR + p·NR + l] = b[s·NR + l][p]`.
fn pack_b_cols(b: &[f32], red: usize, nout: usize) -> Vec<f32> {
    let nstrips = nout.div_ceil(NR);
    let mut out = vec![0.0f32; nstrips * red * NR];
    for s in 0..nstrips {
        let j0 = s * NR;
        let w = NR.min(nout - j0);
        let base = s * red * NR;
        for l in 0..w {
            let row = &b[(j0 + l) * red..(j0 + l + 1) * red];
            for (p, &v) in row.iter().enumerate() {
                out[base + p * NR + l] = v;
            }
        }
    }
    out
}

/// Pack the left operand's rows `[row0, row0+rowc)` × reduction block
/// `[k0, k0+kc)` into `MR`-row groups, reduction-major within a group
/// (`out[g·kc·MR + p·MR + r]`), zero-padding the tail group's rows.
/// `atrans` selects the storage map: `false` → `A(row, p) =
/// a[row·astride + p]` (matmul / matmul_nt), `true` → `A(row, p) =
/// a[p·astride + row]` (matmul_tn's transposed view, where each
/// reduction step's `MR`-chunk is contiguous).
#[allow(clippy::too_many_arguments)]
fn pack_a(
    a: &[f32],
    astride: usize,
    atrans: bool,
    row0: usize,
    rowc: usize,
    k0: usize,
    kc: usize,
    out: &mut Vec<f32>,
) {
    let groups = rowc.div_ceil(MR);
    out.clear();
    out.resize(groups * kc * MR, 0.0);
    for g in 0..groups {
        let r0 = row0 + g * MR;
        let h = MR.min(row0 + rowc - r0);
        let dst = &mut out[g * kc * MR..(g + 1) * kc * MR];
        if atrans {
            for p in 0..kc {
                let src0 = (k0 + p) * astride + r0;
                dst[p * MR..p * MR + h].copy_from_slice(&a[src0..src0 + h]);
            }
        } else {
            for r in 0..h {
                let row = &a[(r0 + r) * astride + k0..(r0 + r) * astride + k0 + kc];
                for (p, &v) in row.iter().enumerate() {
                    dst[p * MR + r] = v;
                }
            }
        }
    }
}

/// `MR×NR` register-tile micro-kernel: accumulate one packed A group
/// against one packed B strip over `kc` reduction steps, loading and
/// storing the live `mr × nr` corner of `out_rows`.  Terms are added in
/// ascending reduction order — the bit-exactness contract.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn micro_kernel(
    out_rows: &mut [f32],
    nout: usize,
    r0: usize,
    mr: usize,
    j0: usize,
    nr: usize,
    apack: &[f32],
    bpack: &[f32],
) {
    let mut acc = [[0.0f32; NR]; MR];
    for r in 0..mr {
        let at = (r0 + r) * nout + j0;
        acc[r][..nr].copy_from_slice(&out_rows[at..at + nr]);
    }
    for (av, bv) in apack.chunks_exact(MR).zip(bpack.chunks_exact(NR)) {
        for r in 0..MR {
            let a = av[r];
            for l in 0..NR {
                acc[r][l] += a * bv[l];
            }
        }
    }
    for r in 0..mr {
        let at = (r0 + r) * nout + j0;
        out_rows[at..at + nr].copy_from_slice(&acc[r][..nr]);
    }
}

/// One row-block task: every k-block × strip for output rows
/// `[row0, row0+rowc)`.  `out_rows` covers exactly those rows.
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    a: &[f32],
    astride: usize,
    atrans: bool,
    bpack: &[f32],
    nout: usize,
    red: usize,
    out_rows: &mut [f32],
    row0: usize,
    rowc: usize,
    apack: &mut Vec<f32>,
) {
    let nstrips = nout.div_ceil(NR);
    let groups = rowc.div_ceil(MR);
    let mut k0 = 0usize;
    while k0 < red {
        let kc = KC.min(red - k0);
        pack_a(a, astride, atrans, row0, rowc, k0, kc, apack);
        for s in 0..nstrips {
            let j0 = s * NR;
            let nr = NR.min(nout - j0);
            let b0 = s * red * NR + k0 * NR;
            let bblk = &bpack[b0..b0 + kc * NR];
            for g in 0..groups {
                let ablk = &apack[g * kc * MR..(g + 1) * kc * MR];
                micro_kernel(out_rows, nout, g * MR, MR.min(rowc - g * MR), j0, nr, ablk, bblk);
            }
        }
        k0 += kc;
    }
}

/// Shared pointer into the output buffer; tasks write disjoint row
/// ranges (see the SAFETY note at the use site).
struct OutPtr(*mut f32);
unsafe impl Sync for OutPtr {}

/// Blocked-GEMM dispatch: sequential for small jobs or 1-thread pools,
/// row-block parallel otherwise.  Every path is bit-identical — the
/// thresholds are latency knobs, never numerics.
#[allow(clippy::too_many_arguments)]
fn gemm(
    a: &[f32],
    astride: usize,
    atrans: bool,
    bpack: &[f32],
    mout: usize,
    nout: usize,
    red: usize,
    pool: &Pool,
) -> Vec<f32> {
    let mut out = vec![0.0f32; mout * nout];
    if mout == 0 || nout == 0 || red == 0 {
        return out; // the empty reduction is exactly the zero matrix
    }
    let nblocks = mout.div_ceil(MC);
    let macs = mout.saturating_mul(nout).saturating_mul(red);
    if pool.threads() == 1 || nblocks == 1 || macs < PAR_MIN_MACS {
        let mut apack = Vec::new();
        for blk in 0..nblocks {
            let row0 = blk * MC;
            let rowc = MC.min(mout - row0);
            gemm_rows(
                a,
                astride,
                atrans,
                bpack,
                nout,
                red,
                &mut out[row0 * nout..(row0 + rowc) * nout],
                row0,
                rowc,
                &mut apack,
            );
        }
    } else {
        let optr = OutPtr(out.as_mut_ptr());
        pool.run(nblocks, &|blk| {
            let row0 = blk * MC;
            let rowc = MC.min(mout - row0);
            // SAFETY: each task reconstructs a &mut over *its own*
            // disjoint row range of `out`, which outlives `pool.run`
            // (run returns only after every task completed).
            let out_rows = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add(row0 * nout), rowc * nout)
            };
            let mut apack = Vec::new();
            gemm_rows(a, astride, atrans, bpack, nout, red, out_rows, row0, rowc, &mut apack);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: &[f32], shape: &[usize]) -> Tensor {
        Tensor::from_vec(data.to_vec(), shape)
    }

    #[test]
    fn matmul_small() {
        // (2,3)·(3,2)
        let a = t(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let b = t(&[7.0, 8.0, 9.0, 10.0, 11.0, 12.0], &[3, 2]);
        let c = a.matmul(&b);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
        assert_eq!(c.data, a.matmul_naive(&b).data);
    }

    #[test]
    fn matmul_variants_agree_with_explicit_transpose() {
        let a = t(&[1.0, -2.0, 0.5, 3.0, 4.0, -1.0], &[2, 3]);
        let b = t(&[2.0, 1.0, 0.0, -1.0, 1.5, 2.5], &[2, 3]);
        // aᵀ·b via matmul_tn == transpose(a)·b
        let at = t(&[1.0, 3.0, -2.0, 4.0, 0.5, -1.0], &[3, 2]);
        assert_eq!(a.matmul_tn(&b).data, at.matmul(&b).data);
        // a·bᵀ via matmul_nt == a·transpose(b)
        let bt = t(&[2.0, -1.0, 1.0, 1.5, 0.0, 2.5], &[3, 2]);
        assert_eq!(a.matmul_nt(&b).data, a.matmul(&bt).data);
    }

    /// Spans several row-blocks, strips and a k-block boundary so the
    /// packed/blocked machinery (not just the micro path) is exercised
    /// in-module; the exhaustive sweep lives in tests/kernels.rs.
    #[test]
    fn blocked_kernels_match_naive_across_block_boundaries() {
        let mut rng = crate::util::Rng::new(0x9E77);
        let (m, k, n) = (2 * MC + 3, KC + 17, 3 * NR + 5);
        let a = Tensor::from_vec(
            (0..m * k).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            &[m, k],
        );
        let b = Tensor::from_vec(
            (0..k * n).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            &[k, n],
        );
        let bt = Tensor::from_vec(
            (0..n * k).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            &[n, k],
        );
        let pool = Pool::new(2);
        assert_eq!(a.matmul_with(&b, &pool).data, a.matmul_naive(&b).data);
        assert_eq!(a.matmul_nt_with(&bt, &pool).data, a.matmul_nt_naive(&bt).data);
        let g = Tensor::from_vec(
            (0..m * n).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            &[m, n],
        );
        assert_eq!(a.matmul_tn_with(&g, &pool).data, a.matmul_tn_naive(&g).data);
    }

    #[test]
    fn cols_is_the_trailing_shape_product() {
        // Regression for the old `data.len() / shape[0].max(1)`, which
        // silently collapsed empty tensors to zero width.
        assert_eq!(t(&[], &[0, 5]).cols(), 5, "empty tensor keeps its row width");
        assert_eq!(t(&[0.0; 6], &[2, 3]).cols(), 3);
        assert_eq!(t(&[0.0; 24], &[2, 3, 4]).cols(), 12, "trailing dims multiply");
        assert_eq!(t(&[0.0; 3], &[3]).cols(), 1, "rank-1 tensors are column vectors");
        assert_eq!(t(&[], &[0]).cols(), 1);
    }

    #[test]
    #[should_panic(expected = "rank-0")]
    fn cols_rejects_rank0() {
        let scalar = Tensor { shape: vec![], data: vec![1.0] };
        let _ = scalar.cols();
    }

    /// Zero-sized dims flow through every variant: shapes stay
    /// conformable (the old cols() made these panic) and outputs are
    /// the exact zero/empty matrices the naive loops produce.
    #[test]
    fn zero_sized_gemm_dims_are_well_defined() {
        let pool = Pool::new(2);
        let a = Tensor::zeros(&[0, 5]);
        let b = t(&(0..15).map(|x| x as f32).collect::<Vec<_>>(), &[5, 3]);
        let c = a.matmul_with(&b, &pool);
        assert_eq!(c.shape, vec![0, 3]);
        assert!(c.data.is_empty());

        let a = t(&[1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::zeros(&[2, 0]);
        let c = a.matmul_with(&b, &pool);
        assert_eq!((c.shape.clone(), c.data.len()), (vec![2, 0], 0));

        // k == 0: the empty reduction is the zero matrix.
        let a = Tensor::zeros(&[3, 0]);
        let b = Tensor::zeros(&[0, 4]);
        let c = a.matmul_with(&b, &pool);
        assert_eq!(c.shape, vec![3, 4]);
        assert_eq!(c.data, vec![0.0; 12]);
        assert_eq!(c.data, a.matmul_naive(&b).data);

        // And the transposed variants.
        let x = Tensor::zeros(&[0, 4]);
        let g = Tensor::zeros(&[0, 2]);
        let dw = x.matmul_tn_with(&g, &pool);
        assert_eq!(dw.shape, vec![4, 2]);
        assert_eq!(dw.data, vec![0.0; 8]);
        let dz = Tensor::zeros(&[0, 2]);
        let w = Tensor::zeros(&[4, 2]);
        let dx = dz.matmul_nt_with(&w, &pool);
        assert_eq!((dx.shape.clone(), dx.data.len()), (vec![0, 4], 0));
    }

    #[test]
    fn bias_and_col_sums() {
        let mut x = t(&[1.0, 2.0, 3.0, 4.0], &[2, 2]);
        x.add_bias(&[10.0, 20.0]);
        assert_eq!(x.data, vec![11.0, 22.0, 13.0, 24.0]);
        assert_eq!(x.col_sums(), vec![24.0, 46.0]);
    }

    #[test]
    fn rounding_and_overflow_probe() {
        let mut x = t(&[1.0, 1e6, -3.0e-8], &[3]);
        assert!(!x.has_non_finite());
        x.round_to(Format::Fp16);
        assert!(x.data[1].is_infinite(), "fp16 overflow must surface as inf");
        assert!(x.has_non_finite());
        let mut y = t(&[1.0, 2.0], &[2]);
        y.round_to(Format::Fp32);
        assert_eq!(y.data, vec![1.0, 2.0]);
        // The slice fast path must surface ±inf overflow at any
        // position, including unaligned chunk tails: a 19-element
        // tensor (16-lane chunk + 3-lane tail) with overflows in both
        // regions and both signs.
        let mut z = Tensor::zeros(&[19]);
        z.data[3] = 1e6; // in the vector body
        z.data[17] = -1e6; // in the scalar tail
        z.round_to(Format::Fp16);
        assert_eq!(z.data[3], f32::INFINITY, "body overflow must round to +inf");
        assert_eq!(z.data[17], f32::NEG_INFINITY, "tail overflow must round to -inf");
        assert!(z.has_non_finite());
        assert_eq!(z.data[0], 0.0, "non-overflowing lanes unaffected");
    }
}
