//! Adam with mixed-precision plumbing: loss-scale unscaling, overflow
//! detection (`found_inf`), and master-weight accumulation.
//!
//! The training losses are scaled by the [`crate::quant::LossScaler`]'s
//! current scale before backprop; this optimizer is the other half of
//! that contract: it probes the *scaled* gradients for ±inf/NaN (an FP16
//! backward overflow shows up here), skips the whole update on overflow,
//! and otherwise unscales and applies the step to each parameter's
//! full-precision accumulator (the FP32 master for PL/FP16 layers, the
//! working copy itself for BF16/FP32 layers — Table II's master-weight
//! column), re-rounding the working copy to its storage format.

use crate::util::json::{hex_f32s, parse_hex_f32s, Json, JsonError};

use super::layers::Param;

/// Adam (Kingma & Ba) with bias correction.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// FSM-visible telemetry.
    pub steps_applied: u64,
    pub steps_skipped: u64,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
            steps_applied: 0,
            steps_skipped: 0,
        }
    }

    /// Serialize the full optimizer state — step count, first/second
    /// moments (per parameter, in `params_mut()` order) and telemetry —
    /// bit-exactly.  The moment vectors may be empty if no step has run.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lr", Json::Str(hex_f32s(&[self.lr]))),
            ("beta1", Json::Str(hex_f32s(&[self.beta1]))),
            ("beta2", Json::Str(hex_f32s(&[self.beta2]))),
            ("eps", Json::Str(hex_f32s(&[self.eps]))),
            ("t", Json::Num(f64::from(self.t))),
            ("m", Json::Arr(self.m.iter().map(|v| Json::Str(hex_f32s(v))).collect())),
            ("v", Json::Arr(self.v.iter().map(|v| Json::Str(hex_f32s(v))).collect())),
            ("steps_applied", Json::Num(self.steps_applied as f64)),
            ("steps_skipped", Json::Num(self.steps_skipped as f64)),
        ])
    }

    /// Rebuild an optimizer from an [`Adam::to_json`] snapshot.
    pub fn from_json(v: &Json) -> Result<Adam, JsonError> {
        let moments = |key: &str| -> Result<Vec<Vec<f32>>, JsonError> {
            v.req_arr(key)?
                .iter()
                .map(|e| {
                    let s = e
                        .as_str()
                        .ok_or_else(|| JsonError { msg: format!("bad {key} entry"), pos: 0 })?;
                    parse_hex_f32s(s)
                })
                .collect()
        };
        Ok(Adam {
            lr: v.req_f32_bits("lr")?,
            beta1: v.req_f32_bits("beta1")?,
            beta2: v.req_f32_bits("beta2")?,
            eps: v.req_f32_bits("eps")?,
            t: v.req_u64("t")? as i32,
            m: moments("m")?,
            v: moments("v")?,
            steps_applied: v.req_u64("steps_applied")?,
            steps_skipped: v.req_u64("steps_skipped")?,
        })
    }

    /// Apply one step over `params` whose `grad` buffers hold gradients
    /// of the *scaled* loss.  Returns `found_inf`: true when any
    /// gradient is non-finite, in which case nothing is updated (the
    /// conditional-skip path of scaled training).
    pub fn step(&mut self, mut params: Vec<&mut Param>, loss_scale: f32) -> bool {
        let total_elems: usize = params.iter().map(|p| p.elems()).sum();
        let _span =
            crate::obs::trace::span(crate::obs::trace::Kernel::AdamStep, [total_elems, 0, 0], 1);
        if self.m.is_empty() {
            self.m = params.iter().map(|p| vec![0.0; p.elems()]).collect();
            self.v = params.iter().map(|p| vec![0.0; p.elems()]).collect();
        }
        assert_eq!(self.m.len(), params.len(), "optimizer/param count drifted");
        let found_inf =
            params.iter().any(|p| p.grad.iter().any(|g| !g.is_finite()));
        if found_inf {
            self.steps_skipped += 1;
            return true;
        }
        self.t += 1;
        let inv_scale = 1.0 / loss_scale;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for (pi, p) in params.iter_mut().enumerate() {
            let (ms, vs) = (&mut self.m[pi], &mut self.v[pi]);
            // Stage the full-precision update element-wise, then derive
            // the working copy in one vectorized `round_slice` pass
            // (`Param::commit`) — bit-identical to per-element rounding,
            // but the master-weight round-trip runs at slice throughput.
            for j in 0..p.elems() {
                let g = p.grad[j] * inv_scale;
                ms[j] = self.beta1 * ms[j] + (1.0 - self.beta1) * g;
                vs[j] = self.beta2 * vs[j] + (1.0 - self.beta2) * g * g;
                let mhat = ms[j] / bc1;
                let vhat = vs[j] / bc2;
                let x = p.accum_at(j) - self.lr * mhat / (vhat.sqrt() + self.eps);
                p.write_accum(j, x);
            }
            p.commit();
        }
        self.steps_applied += 1;
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::Format;

    fn param(vals: &[f32]) -> Param {
        Param::new(vals.to_vec(), &[vals.len()], Format::Fp32, false)
    }

    #[test]
    fn first_step_moves_by_lr() {
        // With bias correction, step 1 moves ≈ lr·sign(g) for any g.
        let mut p = param(&[1.0]);
        p.grad[0] = 0.5;
        let mut opt = Adam::new(0.01);
        assert!(!opt.step(vec![&mut p], 1.0));
        assert!((p.value.data[0] - (1.0 - 0.01)).abs() < 1e-4, "got {}", p.value.data[0]);
        assert_eq!(opt.steps_applied, 1);
    }

    #[test]
    fn overflow_skips_update_entirely() {
        let mut p = param(&[1.0, 2.0]);
        p.grad[0] = f32::INFINITY;
        p.grad[1] = 0.1;
        let mut opt = Adam::new(0.1);
        assert!(opt.step(vec![&mut p], 1024.0), "inf grad must report found_inf");
        assert_eq!(p.value.data, vec![1.0, 2.0], "skipped update must not move weights");
        assert_eq!(opt.steps_skipped, 1);
        assert_eq!(opt.steps_applied, 0);
        // And the optimizer state is untouched: a clean follow-up step
        // behaves like a first step.
        p.grad[0] = 0.5;
        p.grad[1] = 0.5;
        assert!(!opt.step(vec![&mut p], 1.0));
        assert!((p.value.data[0] - 0.9).abs() < 1e-4);
    }

    #[test]
    fn unscaling_matches_unscaled_run() {
        // Same gradients fed once scaled (with matching unscale) and once
        // raw must produce identical trajectories.
        let mut a = param(&[0.3, -0.7]);
        let mut b = param(&[0.3, -0.7]);
        let mut oa = Adam::new(0.05);
        let mut ob = Adam::new(0.05);
        for step in 0..20 {
            let g = [0.1 + step as f32 * 0.01, -0.2];
            a.grad.copy_from_slice(&g);
            b.grad.copy_from_slice(&[g[0] * 4096.0, g[1] * 4096.0]);
            oa.step(vec![&mut a], 1.0);
            ob.step(vec![&mut b], 4096.0);
        }
        for (x, y) in a.value.data.iter().zip(&b.value.data) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn json_round_trip_continues_trajectory_bit_identically() {
        let mut p1 = param(&[0.3, -0.7, 1.1]);
        let mut opt = Adam::new(0.05);
        for step in 0..13usize {
            p1.grad.iter_mut().enumerate().for_each(|(i, g)| *g = 0.1 * (step + i) as f32);
            opt.step(vec![&mut p1], 1.0);
        }
        let mut p2 = p1.clone();
        let mut restored = Adam::from_json(&opt.to_json()).unwrap();
        for step in 0..20usize {
            let gs: Vec<f32> = (0..3).map(|i| -0.03 * (step * i) as f32).collect();
            p1.grad.copy_from_slice(&gs);
            p2.grad.copy_from_slice(&gs);
            assert_eq!(opt.step(vec![&mut p1], 2.0), restored.step(vec![&mut p2], 2.0));
            for (a, b) in p1.value.data.iter().zip(&p2.value.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // Fresh (never-stepped) optimizer round-trips its empty moments.
        let fresh = Adam::from_json(&Adam::new(0.01).to_json()).unwrap();
        assert!(fresh.m.is_empty() && fresh.v.is_empty());
        assert_eq!(fresh.t, 0);
    }

    #[test]
    fn master_accumulates_through_fp16_storage() {
        let mut p = Param::new(vec![1.0], &[1], Format::Fp16, true);
        let mut opt = Adam::new(1e-4);
        for _ in 0..50 {
            p.grad[0] = 1.0;
            opt.step(vec![&mut p], 1.0);
        }
        let master = p.master.as_ref().unwrap()[0];
        assert!(master < 1.0, "master must move");
        assert_eq!(
            p.value.data[0],
            crate::quant::formats::fp16_round(master),
            "working copy must be the rounded master"
        );
    }
}
