//! A small reusable scoped-thread pool for the CPU executor's kernels.
//!
//! The GEMM kernels split their *output rows* into independent blocks;
//! this pool runs those blocks concurrently.  Because every output
//! element is written by exactly one task and each task performs the
//! same f32 accumulation sequence as the sequential blocked kernel,
//! results are **bit-exact regardless of thread count** — the pool
//! changes wall-clock, never numerics (asserted in `tests/kernels.rs`
//! and the cross-thread training-determinism tests).
//!
//! Design constraints (same as the rest of the crate): `std` only, no
//! crates.io.  Workers are long-lived (`spawn` per GEMM would dwarf the
//! small training-step kernels) and coordinate through one mutex +
//! two condvars:
//!
//! * [`Pool::run`] installs a job (an erased `&dyn Fn(usize)` plus an
//!   atomic task cursor), bumps an epoch and wakes every worker;
//! * each worker claims task indices from the shared cursor until the
//!   job drains, then checks out of the epoch;
//! * `run` itself participates (so a 1-thread pool is just an inline
//!   loop) and only returns once **every** worker has checked out —
//!   that check-out protocol is what makes the borrowed closure safe
//!   to share without `'static`.
//!
//! Sizing: [`Pool::global`] reads the `APDRL_THREADS` environment
//! variable once (default: `available_parallelism` capped at 8, the
//! regime where the executor's row-block granularity still scales).
//! Tests and `apdrl train --threads N` build explicit [`Pool::new`]
//! instances instead of mutating the process environment.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Environment variable naming the executor's thread count.
pub const ENV_THREADS: &str = "APDRL_THREADS";

/// Hard cap on pool size (a tripwire against `APDRL_THREADS=1e9`).
pub const MAX_THREADS: usize = 64;

/// Default thread count: the machine's parallelism, capped at 8.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// Parse an `APDRL_THREADS`-shaped value: a positive integer is clamped
/// to [`MAX_THREADS`]; unset, empty, zero or unparsable values fall
/// back to [`default_threads`].  Pure so tests cover it without
/// touching the process environment.
pub fn threads_from(val: Option<&str>) -> usize {
    match val.map(str::trim) {
        Some(v) if !v.is_empty() => match v.parse::<usize>() {
            Ok(n) if n >= 1 => n.min(MAX_THREADS),
            _ => default_threads(),
        },
        _ => default_threads(),
    }
}

/// Type-erased borrowed task closure, lifetime-extended for storage in
/// the shared slot.  The `'static` is a lie the epoch protocol makes
/// good on: `run` installs the job, and does not return until every
/// worker has checked out of the epoch — so no worker holds this
/// reference once the real borrow ends.  (`&dyn Fn + Sync` is `Send`
/// because the pointee is `Sync`, so no unsafe `Send` impl is needed;
/// the only unsafety is the transmute at the install site.)
struct TaskPtr(&'static (dyn Fn(usize) + Sync));

/// Mutex-protected job slot shared with the workers.
struct Slot {
    /// Bumped once per job; workers run each epoch exactly once.
    epoch: u64,
    task: Option<TaskPtr>,
    ntasks: usize,
    /// Shared task cursor for the current epoch.
    cursor: Arc<AtomicUsize>,
    /// Workers that have not yet checked out of the current epoch.
    active: usize,
    /// A worker task panicked this epoch (re-raised by `run`).
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    work: Condvar,
    done: Condvar,
}

/// Reusable worker pool; see the module docs for the protocol.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// Serializes `run` callers; contenders fall back to inline
    /// execution (bit-identical by construction), which also makes an
    /// accidental nested `run` safe instead of a deadlock.
    running: Mutex<()>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads()).finish()
    }
}

impl Pool {
    /// Pool executing on `threads` threads total (the caller counts as
    /// one: `new(1)` spawns nothing and runs inline).  Zero is treated
    /// as one; the count is clamped to [`MAX_THREADS`].
    pub fn new(threads: usize) -> Pool {
        let threads = threads.clamp(1, MAX_THREADS);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                epoch: 0,
                task: None,
                ntasks: 0,
                cursor: Arc::new(AtomicUsize::new(0)),
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("apdrl-pool-{i}"))
                    .spawn(move || worker(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { shared, workers, running: Mutex::new(()) }
    }

    /// The process-wide pool, sized once from `APDRL_THREADS`.
    pub fn global() -> Arc<Pool> {
        static GLOBAL: OnceLock<Arc<Pool>> = OnceLock::new();
        GLOBAL
            .get_or_init(|| {
                Arc::new(Pool::new(threads_from(std::env::var(ENV_THREADS).ok().as_deref())))
            })
            .clone()
    }

    /// Total threads this pool computes with (workers + caller).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Run `f(0), f(1), …, f(ntasks-1)` to completion, distributing
    /// tasks over the workers and the calling thread.  Tasks must be
    /// independent; the assignment of tasks to threads is unspecified
    /// and varies between calls.  Panics in any task are re-raised
    /// here after the whole job has drained.
    pub fn run(&self, ntasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if ntasks == 0 {
            return;
        }
        // Inline paths: trivial jobs, a 1-thread pool, or a second
        // concurrent/nested caller (the workers are busy — results are
        // identical either way, so just compute here).
        let _guard = match (self.workers.is_empty() || ntasks == 1, self.running.try_lock()) {
            (false, Ok(g)) => g,
            _ => {
                for i in 0..ntasks {
                    f(i);
                }
                return;
            }
        };
        let cursor = Arc::new(AtomicUsize::new(0));
        // SAFETY: lifetime-extending transmute (see [`TaskPtr`]) — the
        // epoch check-out barrier below keeps the borrow live for every
        // dereference a worker can make.
        let task: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        {
            let mut slot = self.shared.slot.lock().unwrap();
            debug_assert!(slot.task.is_none(), "pool job slot not drained");
            slot.epoch += 1;
            slot.task = Some(TaskPtr(task));
            slot.ntasks = ntasks;
            slot.cursor = cursor.clone();
            slot.active = self.workers.len();
            self.shared.work.notify_all();
        }
        // The caller participates under the same cursor.
        let caller = catch_unwind(AssertUnwindSafe(|| loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= ntasks {
                break;
            }
            f(i);
        }));
        // Epoch barrier: `f` must stay alive (and this frame must not
        // unwind) until every worker has checked out.
        let worker_panic = {
            let mut slot = self.shared.slot.lock().unwrap();
            while slot.active != 0 {
                slot = self.shared.done.wait(slot).unwrap();
            }
            slot.task = None;
            std::mem::take(&mut slot.panicked)
        };
        // Release the run lock *before* re-raising so a panicking task
        // never poisons it (poison would silently force every later
        // run onto the inline path).
        drop(_guard);
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if worker_panic {
            panic!("apdrl pool: a parallel kernel task panicked");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.shutdown = true;
            self.shared.work.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        // Wait for a new epoch (or shutdown), then lift the job out.
        let (task, ntasks, cursor) = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.epoch != seen && slot.task.is_some() {
                    break;
                }
                slot = shared.work.wait(slot).unwrap();
            }
            seen = slot.epoch;
            let task = slot.task.as_ref().expect("job present").0;
            (task, slot.ntasks, slot.cursor.clone())
        };
        // `run` keeps the (transmuted) closure alive until this
        // worker's check-out below.
        let f = task;
        let result = catch_unwind(AssertUnwindSafe(|| loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= ntasks {
                break;
            }
            f(i);
        }));
        let mut slot = shared.slot.lock().unwrap();
        if result.is_err() {
            slot.panicked = true;
        }
        slot.active -= 1;
        if slot.active == 0 {
            shared.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = Pool::new(4);
        for tasks in [0usize, 1, 2, 3, 17, 100] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} of {tasks}");
            }
        }
    }

    #[test]
    fn single_thread_pool_is_inline() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicU64::new(0);
        pool.run(10, &|i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        let pool = Pool::new(3);
        let total = AtomicU64::new(0);
        for _ in 0..200 {
            pool.run(8, &|i| {
                total.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200 * 36);
    }

    #[test]
    fn oversubscribed_pool_still_completes() {
        // More threads than cores and more tasks than threads.
        let pool = Pool::new(8);
        let hits = AtomicUsize::new(0);
        pool.run(64, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn nested_run_falls_back_inline_instead_of_deadlocking() {
        let pool = Pool::new(2);
        let inner_hits = AtomicUsize::new(0);
        pool.run(2, &|_| {
            pool.run(3, &|_| {
                inner_hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(inner_hits.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn worker_panic_is_propagated_and_pool_survives() {
        let pool = Pool::new(4);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, &|i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "task panic must surface to the caller");
        // The pool still works afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(5, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn threads_from_parses_and_defaults() {
        assert_eq!(threads_from(Some("1")), 1);
        assert_eq!(threads_from(Some("4")), 4);
        assert_eq!(threads_from(Some(" 2 ")), 2);
        assert_eq!(threads_from(Some("1000000")), MAX_THREADS);
        let d = default_threads();
        assert!(d >= 1);
        assert_eq!(threads_from(None), d);
        assert_eq!(threads_from(Some("")), d);
        assert_eq!(threads_from(Some("0")), d);
        assert_eq!(threads_from(Some("lots")), d);
    }

    #[test]
    fn clamps_degenerate_sizes() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::new(2).threads(), 2);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = Pool::global();
        let b = Pool::global();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.threads() >= 1 && a.threads() <= MAX_THREADS);
    }
}
