//! Network layers of the CPU executor: dense + conv (im2col GEMM) with
//! cached forward state, hand-written reverse-mode backward, and the
//! per-layer precision hooks that make a partition plan's formats real.
//!
//! Every layer carries the [`LayerFormats`] the [`ExecPolicy`] routed to
//! it: forward outputs round to the `fwd`/`act` node formats, gradients
//! to the `bwd` format, weights are *stored* in the forward compute
//! format, and FP16-update layers keep an FP32 master copy that the
//! optimizer accumulates into ([`super::adam`]).  FP16 overflow shows up
//! as ±inf in the rounded gradients, which is exactly the `found_inf`
//! signal the loss-scaling FSM consumes.

use std::sync::Arc;

use crate::graph::NetSpec;
use crate::hw::Format;
use crate::obs::trace;
use crate::quant::formats::{round_slice, round_to};
use crate::util::json::{hex_f32s, parse_hex_f32s, Json, JsonError};
use crate::util::Rng;

use super::policy::{ExecPolicy, LayerFormats};
use super::pool::Pool;
use super::tensor::Tensor;

/// Activation applied after a layer's GEMM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Act {
    None,
    Relu,
    Tanh,
}

/// One trainable tensor: the working copy (stored in the layer's compute
/// format), an optional FP32 master, and its gradient buffer.
#[derive(Clone, Debug)]
pub struct Param {
    pub value: Tensor,
    pub master: Option<Vec<f32>>,
    pub grad: Vec<f32>,
    pub store: Format,
}

impl Param {
    pub fn new(data: Vec<f32>, shape: &[usize], store: Format, master: bool) -> Param {
        let master = master.then(|| data.clone());
        let mut value = Tensor::from_vec(data, shape);
        value.round_to(store);
        let grad = vec![0.0; value.elems()];
        Param { value, master, grad, store }
    }

    /// Full-precision accumulator element (master if armed, else working).
    pub fn accum_at(&self, j: usize) -> f32 {
        match &self.master {
            Some(m) => m[j],
            None => self.value.data[j],
        }
    }

    /// Write an updated full-precision element: the master (if armed)
    /// keeps it exact, the working copy re-rounds to the storage format.
    pub fn set(&mut self, j: usize, x: f32) {
        if let Some(m) = &mut self.master {
            m[j] = x;
        }
        self.value.data[j] = round_to(x, self.store);
    }

    /// Stage a full-precision element without touching the working
    /// copy's rounding: into the master when armed, else straight into
    /// the working buffer.  Pair every staging sweep with one
    /// [`Param::commit`] — together they do exactly what per-element
    /// [`Param::set`] does, but with the storage rounding batched into
    /// a single vectorized [`round_slice`] pass.
    pub fn write_accum(&mut self, j: usize, x: f32) {
        match &mut self.master {
            Some(m) => m[j] = x,
            None => self.value.data[j] = x,
        }
    }

    /// Re-derive the working copy from the full-precision accumulator:
    /// copy the master over (when armed) and round the whole buffer to
    /// the storage format in one slice pass.
    pub fn commit(&mut self) {
        if let Some(m) = &self.master {
            self.value.data.copy_from_slice(m);
        }
        round_slice(&mut self.value.data, self.store);
    }

    pub fn elems(&self) -> usize {
        self.value.elems()
    }
}

/// Layer connectivity (the conv case runs through its im2col GEMM).
#[derive(Clone, Debug)]
pub enum Wiring {
    Dense { din: usize, dout: usize },
    Conv2d { in_hw: usize, in_ch: usize, out_ch: usize, k: usize, stride: usize, out_hw: usize },
}

/// One layer: weights `(din, dout)` for dense, `(k·k·cin, cout)` (HWIO
/// flattened) for conv; activations flow as `(batch, features)` rows.
#[derive(Clone, Debug)]
pub struct Layer {
    /// CDFG layer name (`fc0`, `conv1`, …) — the key precision routing
    /// uses, so it must match `graph::builder::layer_dims` naming.
    pub name: String,
    pub wiring: Wiring,
    pub w: Param,
    pub b: Param,
    pub act: Act,
    pub fmt: LayerFormats,
    cache_x: Option<Tensor>,
    cache_a: Option<Tensor>,
}

fn im2col(
    x: &Tensor,
    in_hw: usize,
    in_ch: usize,
    k: usize,
    stride: usize,
    out_hw: usize,
) -> Tensor {
    let bs = x.rows();
    let img_elems = in_hw * in_hw * in_ch;
    let pcols = k * k * in_ch;
    let _span = trace::span(trace::Kernel::Im2col, [bs * out_hw * out_hw, pcols, 0], 1);
    let mut data = vec![0.0f32; bs * out_hw * out_hw * pcols];
    for b in 0..bs {
        let img = &x.data[b * img_elems..(b + 1) * img_elems];
        for oy in 0..out_hw {
            for ox in 0..out_hw {
                let r = ((b * out_hw + oy) * out_hw + ox) * pcols;
                for ky in 0..k {
                    let iy = oy * stride + ky;
                    for kx in 0..k {
                        let ix = ox * stride + kx;
                        let src = (iy * in_hw + ix) * in_ch;
                        let dst = r + (ky * k + kx) * in_ch;
                        data[dst..dst + in_ch].copy_from_slice(&img[src..src + in_ch]);
                    }
                }
            }
        }
    }
    Tensor::from_vec(data, &[bs * out_hw * out_hw, pcols])
}

fn col2im(
    dpatches: &Tensor,
    bs: usize,
    in_hw: usize,
    in_ch: usize,
    k: usize,
    stride: usize,
    out_hw: usize,
) -> Tensor {
    let img_elems = in_hw * in_hw * in_ch;
    let pcols = k * k * in_ch;
    let _span = trace::span(trace::Kernel::Col2im, [bs * out_hw * out_hw, pcols, 0], 1);
    let mut out = Tensor::zeros(&[bs, img_elems]);
    for b in 0..bs {
        let img = &mut out.data[b * img_elems..(b + 1) * img_elems];
        for oy in 0..out_hw {
            for ox in 0..out_hw {
                let r = ((b * out_hw + oy) * out_hw + ox) * pcols;
                let row = &dpatches.data[r..r + pcols];
                for ky in 0..k {
                    let iy = oy * stride + ky;
                    for kx in 0..k {
                        let ix = ox * stride + kx;
                        let src = (iy * in_hw + ix) * in_ch;
                        let dst = (ky * k + kx) * in_ch;
                        for c in 0..in_ch {
                            img[src + c] += row[dst + c];
                        }
                    }
                }
            }
        }
    }
    out
}

impl Layer {
    pub fn dense(
        name: String,
        din: usize,
        dout: usize,
        act: Act,
        fmt: LayerFormats,
        rng: &mut Rng,
    ) -> Layer {
        let w = Param::new(rng.he_uniform(din * dout, din), &[din, dout], fmt.fwd, fmt.master);
        let b = Param::new(vec![0.0; dout], &[dout], fmt.fwd, fmt.master);
        Layer {
            name,
            wiring: Wiring::Dense { din, dout },
            w,
            b,
            act,
            fmt,
            cache_x: None,
            cache_a: None,
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        name: String,
        in_hw: usize,
        in_ch: usize,
        out_ch: usize,
        k: usize,
        stride: usize,
        act: Act,
        fmt: LayerFormats,
        rng: &mut Rng,
    ) -> Layer {
        let out_hw = (in_hw - k) / stride + 1;
        let fan_in = k * k * in_ch;
        let w = Param::new(
            rng.he_uniform(fan_in * out_ch, fan_in),
            &[fan_in, out_ch],
            fmt.fwd,
            fmt.master,
        );
        let b = Param::new(vec![0.0; out_ch], &[out_ch], fmt.fwd, fmt.master);
        Layer {
            name,
            wiring: Wiring::Conv2d { in_hw, in_ch, out_ch, k, stride, out_hw },
            w,
            b,
            act,
            fmt,
            cache_x: None,
            cache_a: None,
        }
    }

    pub fn out_dim(&self) -> usize {
        match self.wiring {
            Wiring::Dense { dout, .. } => dout,
            Wiring::Conv2d { out_ch, out_hw, .. } => out_hw * out_hw * out_ch,
        }
    }

    /// Forward compute on `pool`; returns `(cached input, output)` where
    /// the cached input is the dense input itself or the conv im2col
    /// patch matrix (whose GEMM rows — `batch · oh · ow` — are where
    /// the conv path actually fans out over the pool).
    fn compute(&self, x: &Tensor, pool: &Pool) -> (Tensor, Tensor) {
        let (gemm_in, mut z) = match &self.wiring {
            Wiring::Dense { din, .. } => {
                assert_eq!(x.cols(), *din, "layer {}: input dim", self.name);
                let mut z = x.matmul_with(&self.w.value, pool);
                z.add_bias(&self.b.value.data);
                (x.clone(), z)
            }
            Wiring::Conv2d { in_hw, in_ch, out_ch, k, stride, out_hw } => {
                assert_eq!(x.cols(), in_hw * in_hw * in_ch, "layer {}: input dim", self.name);
                let patches = im2col(x, *in_hw, *in_ch, *k, *stride, *out_hw);
                let mut z = patches.matmul_with(&self.w.value, pool);
                // Per-channel bias while still in (rows, out_ch) GEMM
                // shape, then fold back to (batch, oh·ow·oc) rows.
                z.add_bias(&self.b.value.data);
                z.shape = vec![x.rows(), out_hw * out_hw * out_ch];
                (patches, z)
            }
        };
        z.round_to(self.fmt.fwd);
        match self.act {
            Act::None => {}
            Act::Relu => {
                for v in z.data.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                z.round_to(self.fmt.act);
            }
            Act::Tanh => {
                for v in z.data.iter_mut() {
                    *v = v.tanh();
                }
                z.round_to(self.fmt.act);
            }
        }
        (gemm_in, z)
    }

    /// Forward for training: caches the state backward needs.
    pub fn forward(&mut self, x: &Tensor, pool: &Pool) -> Tensor {
        let (cx, a) = self.compute(x, pool);
        self.cache_x = Some(cx);
        self.cache_a = Some(a.clone());
        a
    }

    /// Forward for inference: no cache writes.
    pub fn eval(&self, x: &Tensor, pool: &Pool) -> Tensor {
        self.compute(x, pool).1
    }

    /// Backward from the output gradient `g`; fills `w.grad`/`b.grad`
    /// when `accum` (a pass that only needs input gradients — DDPG's
    /// critic-through-actor — passes false) and returns the input
    /// gradient.
    pub fn backward(&mut self, g: &Tensor, accum: bool, pool: &Pool) -> Tensor {
        let a = self.cache_a.as_ref().expect("layer backward without forward");
        let mut dz = g.clone();
        match self.act {
            Act::None => {}
            Act::Relu => {
                for (d, &av) in dz.data.iter_mut().zip(a.data.iter()) {
                    if av <= 0.0 {
                        *d = 0.0;
                    }
                }
            }
            Act::Tanh => {
                for (d, &av) in dz.data.iter_mut().zip(a.data.iter()) {
                    *d *= 1.0 - av * av;
                }
            }
        }
        dz.round_to(self.fmt.bwd);
        let x = self.cache_x.as_ref().expect("layer backward without forward");
        match &self.wiring {
            Wiring::Dense { .. } => {
                if accum {
                    let mut dw = x.matmul_tn_with(&dz, pool);
                    dw.round_to(self.fmt.bwd);
                    self.w.grad.copy_from_slice(&dw.data);
                    let mut db = dz.col_sums();
                    round_slice(&mut db, self.fmt.bwd);
                    self.b.grad.copy_from_slice(&db);
                }
                let mut dx = dz.matmul_nt_with(&self.w.value, pool);
                dx.round_to(self.fmt.bwd);
                dx
            }
            Wiring::Conv2d { in_hw, in_ch, out_ch, k, stride, out_hw } => {
                let bs = dz.shape[0];
                dz.shape = vec![bs * out_hw * out_hw, *out_ch];
                if accum {
                    let mut dw = x.matmul_tn_with(&dz, pool);
                    dw.round_to(self.fmt.bwd);
                    self.w.grad.copy_from_slice(&dw.data);
                    let mut db = dz.col_sums();
                    round_slice(&mut db, self.fmt.bwd);
                    self.b.grad.copy_from_slice(&db);
                }
                let dpatches = dz.matmul_nt_with(&self.w.value, pool);
                let mut dx = col2im(&dpatches, bs, *in_hw, *in_ch, *k, *stride, *out_hw);
                dx.round_to(self.fmt.bwd);
                dx
            }
        }
    }
}

/// A stack of layers built from a [`NetSpec`], with precision routed per
/// layer from an [`ExecPolicy`] network tag.  The network owns the
/// [`Pool`] its kernels fan out over (the process-wide `APDRL_THREADS`
/// pool by default; [`Network::with_pool`] rebinds it) — thread count
/// never changes results, only wall-clock.
#[derive(Clone, Debug)]
pub struct Network {
    pub layers: Vec<Layer>,
    pub in_dim: usize,
    pool: Arc<Pool>,
}

impl Network {
    /// Build from `spec` with ReLU between layers and `final_act` on the
    /// head, routing each layer's formats via `policy.layer(tag, name)`.
    pub fn from_spec(
        spec: &NetSpec,
        final_act: Act,
        policy: &ExecPolicy,
        tag: &str,
        rng: &mut Rng,
    ) -> Network {
        Self::build(spec, final_act, |name| policy.layer(tag, name), rng)
    }

    /// Build with one uniform format on every layer (tests, controls).
    pub fn from_spec_uniform(
        spec: &NetSpec,
        final_act: Act,
        fmt: LayerFormats,
        rng: &mut Rng,
    ) -> Network {
        Self::build(spec, final_act, |_| fmt, rng)
    }

    /// Rebind the pool the kernels run on (builder style).
    pub fn with_pool(mut self, pool: Arc<Pool>) -> Network {
        self.pool = pool;
        self
    }

    /// The pool this network computes on.
    pub fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }

    fn build(
        spec: &NetSpec,
        final_act: Act,
        fmt_of: impl Fn(&str) -> LayerFormats,
        rng: &mut Rng,
    ) -> Network {
        let mut layers = Vec::new();
        match spec {
            NetSpec::Mlp { sizes } => {
                let n = sizes.len() - 1;
                for i in 0..n {
                    let name = format!("fc{i}");
                    let act = if i + 1 < n { Act::Relu } else { final_act };
                    let fmt = fmt_of(&name);
                    layers.push(Layer::dense(name, sizes[i], sizes[i + 1], act, fmt, rng));
                }
                Network { layers, in_dim: sizes[0], pool: Pool::global() }
            }
            NetSpec::Conv { in_hw, in_ch, conv, fc } => {
                let total = conv.len() + fc.len();
                let (mut h, mut c) = (*in_hw, *in_ch);
                let mut idx = 0;
                for (i, &(cout, k, s)) in conv.iter().enumerate() {
                    let name = format!("conv{i}");
                    let act = if idx + 1 < total { Act::Relu } else { final_act };
                    let fmt = fmt_of(&name);
                    layers.push(Layer::conv(name, h, c, cout, k, s, act, fmt, rng));
                    h = (h - k) / s + 1;
                    c = cout;
                    idx += 1;
                }
                let mut din = h * h * c;
                for (j, &dout) in fc.iter().enumerate() {
                    let name = format!("fc{j}");
                    let act = if idx + 1 < total { Act::Relu } else { final_act };
                    let fmt = fmt_of(&name);
                    layers.push(Layer::dense(name, din, dout, act, fmt, rng));
                    din = dout;
                    idx += 1;
                }
                Network { layers, in_dim: in_hw * in_hw * in_ch, pool: Pool::global() }
            }
        }
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().expect("empty network").out_dim()
    }

    /// Training forward (caches per-layer state).
    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        let pool = self.pool.clone();
        let mut cur = x.clone();
        for layer in self.layers.iter_mut() {
            cur = layer.forward(&cur, &pool);
        }
        cur
    }

    /// Inference forward (no caches touched; usable on `&self`).
    pub fn infer(&self, x: &Tensor) -> Tensor {
        let mut cur = x.clone();
        for layer in &self.layers {
            cur = layer.eval(&cur, &self.pool);
        }
        cur
    }

    /// Backward from the output gradient; returns the input gradient.
    pub fn backward(&mut self, g: &Tensor, accum: bool) -> Tensor {
        let pool = self.pool.clone();
        let mut grad = g.clone();
        for layer in self.layers.iter_mut().rev() {
            grad = layer.backward(&grad, accum, &pool);
        }
        grad
    }

    pub fn zero_grads(&mut self) {
        for layer in self.layers.iter_mut() {
            layer.w.grad.fill(0.0);
            layer.b.grad.fill(0.0);
        }
    }

    /// Scaled-gradient overflow probe — used to gate *joint* multi-network
    /// updates so a skipped step skips every network (Fig 9's conditional
    /// skip is all-or-nothing).
    pub fn has_non_finite_grads(&self) -> bool {
        self.layers.iter().any(|l| {
            l.w.grad.iter().chain(l.b.grad.iter()).any(|g| !g.is_finite())
        })
    }

    /// All trainable params in stable `[w0, b0, w1, b1, …]` order (the
    /// optimizer keys its state by position).
    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        let mut out = Vec::new();
        for layer in self.layers.iter_mut() {
            out.push(&mut layer.w);
            out.push(&mut layer.b);
        }
        out
    }

    /// Target-network hard sync: copy `src`'s full-precision weights and
    /// re-round into this network's own storage formats.
    pub fn copy_weights_from(&mut self, src: &Network) {
        for (dst, s) in self.layers.iter_mut().zip(&src.layers) {
            copy_param(&mut dst.w, &s.w);
            copy_param(&mut dst.b, &s.b);
        }
    }

    /// Polyak soft update `θ' ← τθ + (1−τ)θ'` (DDPG targets).
    pub fn soft_update_from(&mut self, src: &Network, tau: f32) {
        for (dst, s) in self.layers.iter_mut().zip(&src.layers) {
            soft_param(&mut dst.w, &s.w, tau);
            soft_param(&mut dst.b, &s.b, tau);
        }
    }

    /// Per-layer `(name, formats)` — what the routing assertions inspect.
    pub fn layer_formats(&self) -> Vec<(String, LayerFormats)> {
        self.layers.iter().map(|l| (l.name.clone(), l.fmt)).collect()
    }

    /// Serialize every parameter bit-exactly for checkpoints: per layer
    /// the working copy and (when armed) the FP32 master, as IEEE-754
    /// hex.  Gradients are not saved — they are fully overwritten before
    /// each optimizer step.
    pub fn weights_to_json(&self) -> Json {
        let layer_json = |l: &Layer| {
            let mut pairs = vec![
                ("name", Json::Str(l.name.clone())),
                ("w", Json::Str(hex_f32s(&l.w.value.data))),
                ("b", Json::Str(hex_f32s(&l.b.value.data))),
            ];
            if let Some(m) = &l.w.master {
                pairs.push(("w_master", Json::Str(hex_f32s(m))));
            }
            if let Some(m) = &l.b.master {
                pairs.push(("b_master", Json::Str(hex_f32s(m))));
            }
            Json::obj(pairs)
        };
        Json::Arr(self.layers.iter().map(layer_json).collect())
    }

    /// Restore parameters saved by [`Network::weights_to_json`] into a
    /// structurally identical network (same spec + policy).  Raw bits are
    /// written back without re-rounding, so the restored network computes
    /// bit-identically to the one that was saved.
    pub fn restore_weights(&mut self, v: &Json) -> Result<(), JsonError> {
        let arr = v
            .as_arr()
            .ok_or_else(|| JsonError { msg: "weights: expected array".into(), pos: 0 })?;
        if arr.len() != self.layers.len() {
            return Err(JsonError {
                msg: format!("weights: {} layers saved, {} built", arr.len(), self.layers.len()),
                pos: 0,
            });
        }
        for (layer, saved) in self.layers.iter_mut().zip(arr) {
            let name = saved.req_str("name")?;
            if name != layer.name {
                return Err(JsonError {
                    msg: format!("weights: layer {:?} saved as {name:?}", layer.name),
                    pos: 0,
                });
            }
            restore_param(&mut layer.w, saved, "w", "w_master")?;
            restore_param(&mut layer.b, saved, "b", "b_master")?;
        }
        Ok(())
    }
}

fn restore_param(
    p: &mut Param,
    saved: &Json,
    key: &str,
    master_key: &str,
) -> Result<(), JsonError> {
    let data = parse_hex_f32s(saved.req_str(key)?)?;
    if data.len() != p.elems() {
        return Err(JsonError {
            msg: format!("weights: {key} has {} elems, expected {}", data.len(), p.elems()),
            pos: 0,
        });
    }
    p.value.data = data;
    match (&mut p.master, saved.get(master_key)) {
        (Some(m), Some(j)) => {
            let data = parse_hex_f32s(
                j.as_str()
                    .ok_or_else(|| JsonError { msg: format!("bad {master_key}"), pos: 0 })?,
            )?;
            if data.len() != m.len() {
                return Err(JsonError { msg: format!("{master_key} length mismatch"), pos: 0 });
            }
            *m = data;
        }
        (None, None) => {}
        _ => {
            return Err(JsonError {
                msg: format!("weights: master mismatch on {key} (saved vs built policy differ)"),
                pos: 0,
            })
        }
    }
    Ok(())
}

fn copy_param(dst: &mut Param, src: &Param) {
    assert_eq!(dst.elems(), src.elems());
    for j in 0..dst.elems() {
        let x = src.accum_at(j);
        dst.write_accum(j, x);
    }
    dst.commit();
}

fn soft_param(dst: &mut Param, src: &Param, tau: f32) {
    assert_eq!(dst.elems(), src.elems());
    for j in 0..dst.elems() {
        let x = tau * src.accum_at(j) + (1.0 - tau) * dst.accum_at(j);
        dst.write_accum(j, x);
    }
    dst.commit();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp32_net(spec: &NetSpec, final_act: Act, seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        Network::from_spec_uniform(spec, final_act, LayerFormats::fp32(), &mut rng)
    }

    /// Scalar probe loss L = Σ out ⊙ probe, so dL/dout = probe.
    fn probe_loss(out: &Tensor, probe: &Tensor) -> f64 {
        out.data.iter().zip(&probe.data).map(|(&o, &p)| o as f64 * p as f64).sum()
    }

    /// Finite-difference check of dL/dθ for every param of `net`.
    fn gradcheck(net: &mut Network, x: &Tensor, tol: f64) {
        let mut rng = Rng::new(0xC0FFEE);
        let out = net.forward(x);
        let probe = Tensor::from_vec(
            (0..out.elems()).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            &out.shape,
        );
        net.zero_grads();
        net.backward(&probe, true);
        // Collect analytic grads, then perturb each param elementwise.
        let analytic: Vec<Vec<f32>> =
            net.params_mut().iter().map(|p| p.grad.clone()).collect();
        let eps = 1e-3f32;
        for pi in 0..analytic.len() {
            for j in 0..analytic[pi].len() {
                let orig = {
                    let mut params = net.params_mut();
                    let v = params[pi].value.data[j];
                    params[pi].value.data[j] = v + eps;
                    v
                };
                let lp = probe_loss(&net.infer(x), &probe);
                {
                    let mut params = net.params_mut();
                    params[pi].value.data[j] = orig - eps;
                }
                let lm = probe_loss(&net.infer(x), &probe);
                {
                    let mut params = net.params_mut();
                    params[pi].value.data[j] = orig;
                }
                let numeric = (lp - lm) / (2.0 * eps as f64);
                let got = analytic[pi][j] as f64;
                let scale = numeric.abs().max(got.abs()).max(1.0);
                assert!(
                    (numeric - got).abs() / scale < tol,
                    "param {pi}[{j}]: numeric {numeric} vs analytic {got}"
                );
            }
        }
    }

    #[test]
    fn dense_mlp_gradcheck() {
        let spec = NetSpec::mlp(&[3, 8, 2]);
        let mut net = fp32_net(&spec, Act::None, 11);
        let mut rng = Rng::new(5);
        let x = Tensor::from_vec(
            (0..2 * 3).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            &[2, 3],
        );
        gradcheck(&mut net, &x, 2e-2);
    }

    #[test]
    fn tanh_head_gradcheck() {
        let spec = NetSpec::mlp(&[4, 6, 2]);
        let mut net = fp32_net(&spec, Act::Tanh, 13);
        let mut rng = Rng::new(7);
        let x = Tensor::from_vec(
            (0..2 * 4).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            &[2, 4],
        );
        gradcheck(&mut net, &x, 2e-2);
    }

    #[test]
    fn conv_net_gradcheck() {
        let spec = NetSpec::Conv { in_hw: 6, in_ch: 2, conv: vec![(3, 3, 1)], fc: vec![4] };
        let mut net = fp32_net(&spec, Act::None, 17);
        let mut rng = Rng::new(9);
        let x = Tensor::from_vec(
            (0..2 * 6 * 6 * 2).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
            &[2, 6 * 6 * 2],
        );
        gradcheck(&mut net, &x, 3e-2);
    }

    #[test]
    fn conv_shapes_match_cdfg_dims() {
        // The Table III mini pixel net: 12×12×4 → conv(8,4,2) → 5×5×8 →
        // conv(16,3,1) → 3×3×16 → fc 128 → fc 4.
        let spec = NetSpec::Conv {
            in_hw: 12,
            in_ch: 4,
            conv: vec![(8, 4, 2), (16, 3, 1)],
            fc: vec![128, 4],
        };
        let net = fp32_net(&spec, Act::None, 3);
        assert_eq!(net.in_dim, 12 * 12 * 4);
        assert_eq!(net.out_dim(), 4);
        assert_eq!(
            net.layers.iter().map(|l| l.name.as_str()).collect::<Vec<_>>(),
            vec!["conv0", "conv1", "fc0", "fc1"]
        );
        let x = Tensor::zeros(&[3, 12 * 12 * 4]);
        let y = net.infer(&x);
        assert_eq!(y.shape, vec![3, 4]);
    }

    #[test]
    fn quantized_storage_rounds_weights_and_outputs() {
        use crate::quant::formats::bf16_round;
        let fmt = LayerFormats {
            fwd: Format::Bf16,
            act: Format::Bf16,
            bwd: Format::Bf16,
            update: Format::Bf16,
            master: false,
        };
        let spec = NetSpec::mlp(&[4, 8, 2]);
        let mut rng = Rng::new(21);
        let net = Network::from_spec_uniform(&spec, Act::None, fmt, &mut rng);
        for layer in &net.layers {
            for &w in &layer.w.value.data {
                assert_eq!(w.to_bits(), bf16_round(w).to_bits(), "weight not BF16-resident");
            }
            assert!(layer.w.master.is_none(), "BF16 layers keep no master (Table II)");
        }
        let x = Tensor::from_vec(vec![0.3, -0.7, 1.1, 0.05], &[1, 4]);
        let y = net.infer(&x);
        for &v in &y.data {
            assert_eq!(v.to_bits(), bf16_round(v).to_bits(), "output not BF16");
        }
    }

    #[test]
    fn master_backed_param_survives_tiny_updates() {
        // FP16 working copy + FP32 master: a sub-ULP update accumulates
        // in the master even when the working copy cannot represent it.
        let mut p = Param::new(vec![1.0], &[1], Format::Fp16, true);
        for _ in 0..10 {
            let x = p.accum_at(0) + 1e-5;
            p.set(0, x);
        }
        let m = p.master.as_ref().unwrap()[0];
        assert!((m - 1.0001).abs() < 1e-6, "master drifted: {m}");
        // Working copy is the fp16 rounding of the master.
        assert_eq!(p.value.data[0], crate::quant::formats::fp16_round(m));
    }

    /// The batched staging path (`write_accum` sweep + one `commit`)
    /// must land bit-identically where per-element `set` does, for both
    /// master-armed and master-less storage formats.
    #[test]
    fn write_accum_commit_matches_per_element_set() {
        for (store, master) in [(Format::Fp16, true), (Format::Bf16, false), (Format::Fp32, false)]
        {
            let vals = vec![0.1f32, -2.5, 1e-3, 700.0, -0.0];
            let mut a = Param::new(vals.clone(), &[5], store, master);
            let mut b = Param::new(vals, &[5], store, master);
            let mut rng = Rng::new(0xC0);
            for step in 0..4 {
                for j in 0..a.elems() {
                    let x = rng.uniform_in(-3.0, 3.0) as f32 + step as f32;
                    a.set(j, x);
                    b.write_accum(j, x);
                }
                b.commit();
                for j in 0..a.elems() {
                    assert_eq!(
                        a.value.data[j].to_bits(),
                        b.value.data[j].to_bits(),
                        "{store:?} step {step} elem {j}: working copies diverged"
                    );
                    assert_eq!(a.accum_at(j).to_bits(), b.accum_at(j).to_bits());
                }
            }
        }
    }

    #[test]
    fn networks_compute_identically_on_any_pool() {
        use std::sync::Arc;
        let spec = NetSpec::mlp(&[6, 48, 3]);
        let x = {
            let mut rng = Rng::new(40);
            Tensor::from_vec(
                (0..40 * 6).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
                &[40, 6],
            )
        };
        let base = fp32_net(&spec, Act::None, 23).infer(&x);
        for threads in [1usize, 3] {
            let net = fp32_net(&spec, Act::None, 23).with_pool(Arc::new(Pool::new(threads)));
            assert_eq!(net.infer(&x).data, base.data, "{threads}-thread pool diverged");
        }
    }

    #[test]
    fn weight_round_trip_is_bit_identical_including_masters() {
        let fmt = LayerFormats {
            fwd: Format::Fp16,
            act: Format::Fp16,
            bwd: Format::Fp16,
            update: Format::Fp32,
            master: true,
        };
        let spec = NetSpec::mlp(&[4, 8, 2]);
        let mut rng = Rng::new(77);
        let mut src = Network::from_spec_uniform(&spec, Act::None, fmt, &mut rng);
        // Nudge masters off the working copies so the round trip proves
        // both are carried independently.
        for p in src.params_mut() {
            for j in 0..p.elems() {
                let x = p.accum_at(j) + 1e-5;
                p.write_accum(j, x);
            }
            p.commit();
        }
        let saved = src.weights_to_json();
        let mut rng2 = Rng::new(1234); // different init — must be overwritten
        let mut dst = Network::from_spec_uniform(&spec, Act::None, fmt, &mut rng2);
        dst.restore_weights(&saved).unwrap();
        for (a, b) in src.layers.iter().zip(&dst.layers) {
            for (x, y) in a.w.value.data.iter().zip(&b.w.value.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in
                a.w.master.as_ref().unwrap().iter().zip(b.w.master.as_ref().unwrap())
            {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // Shape/name mismatches are hard errors, not silent corruption.
        let mut other =
            Network::from_spec_uniform(&NetSpec::mlp(&[4, 6, 2]), Act::None, fmt, &mut rng2);
        assert!(other.restore_weights(&saved).is_err());
    }

    #[test]
    fn target_sync_and_soft_update() {
        let spec = NetSpec::mlp(&[2, 4, 1]);
        let a = fp32_net(&spec, Act::None, 1);
        let mut b = fp32_net(&spec, Act::None, 2);
        b.copy_weights_from(&a);
        let x = Tensor::from_vec(vec![0.5, -0.5], &[1, 2]);
        assert_eq!(a.infer(&x).data, b.infer(&x).data);
        // Soft update with τ=1 is a hard copy.
        let mut c = fp32_net(&spec, Act::None, 3);
        c.soft_update_from(&a, 1.0);
        assert_eq!(a.infer(&x).data, c.infer(&x).data);
    }
}
