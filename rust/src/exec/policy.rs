//! Plan → executor precision routing.
//!
//! The planner's [`PlanOutcome`] carries the solved schedule with one
//! format-by-name per CDFG node (`online/fc0/fwd`, `actor/conv1/bwd`,
//! `critic/fc2/update`, …).  [`ExecPolicy`] folds that back into the
//! per-(network, layer) table the CPU executor consumes, so a partition
//! plan — local, remote or federated, it's the same wire shape —
//! *literally* decides which layers train in BF16/FP16/FP32:
//!
//! * `fwd`/`act` formats round the layer's pre-activation / activation
//!   outputs (and its resident weights: the store format is the forward
//!   compute format — AIE keeps BF16 weights, PL FP16, PS FP32);
//! * `bwd` rounds the dx/dw/db gradients;
//! * an FP16 `update` node (a PL placement under Alg. 1) arms an FP32
//!   master-weight copy, exactly as [`crate::quant::policy`] dictates;
//! * any FP16 node anywhere arms the [`crate::quant::LossScaler`] FSM
//!   (Table II: FP16 needs loss scaling, BF16/FP32 do not).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::coordinator::planner::PlanOutcome;
use crate::hw::Format;

/// Formats one executor layer runs in, plus its master-weight arming.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerFormats {
    /// Forward GEMM output format (also the weight storage format).
    pub fwd: Format,
    /// Activation output format (the CDFG's separate `act` node; equals
    /// `fwd` for layers without one, i.e. network heads).
    pub act: Format,
    /// Backward dx/dw/db format.
    pub bwd: Format,
    /// Update-node format; FP16 here means "PL update" and arms a master.
    pub update: Format,
    /// Keep an FP32 master copy and apply optimizer math to it.
    pub master: bool,
}

impl LayerFormats {
    pub fn fp32() -> LayerFormats {
        LayerFormats {
            fwd: Format::Fp32,
            act: Format::Fp32,
            bwd: Format::Fp32,
            update: Format::Fp32,
            master: false,
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct PartialFormats {
    fwd: Option<Format>,
    act: Option<Format>,
    bwd: Option<Format>,
    update: Option<Format>,
}

/// Per-(network tag, layer name) precision routing for one training run,
/// derived from a planner schedule (or the all-FP32 control).
#[derive(Clone, Debug, PartialEq)]
pub struct ExecPolicy {
    /// AP-DRL mixed precision (true) vs the FP32 control run.
    pub quantized: bool,
    /// Any FP16 node in the plan → the LossScaler FSM must be armed.
    pub needs_loss_scaling: bool,
    nodes: BTreeMap<(String, String), LayerFormats>,
}

impl ExecPolicy {
    /// The FP32 control: every layer FP32, no scaling, no masters.
    pub fn fp32() -> ExecPolicy {
        ExecPolicy { quantized: false, needs_loss_scaling: false, nodes: BTreeMap::new() }
    }

    /// Fold a solved plan's schedule into executor routing.  Node names
    /// that are not `tag/layer/kind` shaped (losses, soft updates) only
    /// contribute to the loss-scaling decision, mirroring
    /// `PrecisionPolicy::needs_loss_scaling` over *all* nodes.
    pub fn from_outcome(plan: &PlanOutcome) -> Result<ExecPolicy> {
        let mut partial: BTreeMap<(String, String), PartialFormats> = BTreeMap::new();
        let mut needs_loss_scaling = false;
        for step in &plan.schedule {
            let fmt = Format::from_name(&step.format).ok_or_else(|| {
                anyhow!("plan step {}: unknown format {:?}", step.name, step.format)
            })?;
            if fmt == Format::Fp16 {
                needs_loss_scaling = true;
            }
            let mut parts = step.name.split('/');
            let (Some(tag), Some(lname), Some(kind), None) =
                (parts.next(), parts.next(), parts.next(), parts.next())
            else {
                continue;
            };
            let entry = partial.entry((tag.to_string(), lname.to_string())).or_default();
            match kind {
                "fwd" => entry.fwd = Some(fmt),
                "act" => entry.act = Some(fmt),
                "bwd" => entry.bwd = Some(fmt),
                "update" => entry.update = Some(fmt),
                _ => {}
            }
        }
        let nodes = partial
            .into_iter()
            .map(|(key, p)| {
                let fwd = p.fwd.unwrap_or(Format::Fp32);
                let update = p.update.unwrap_or(fwd);
                let lf = LayerFormats {
                    fwd,
                    act: p.act.unwrap_or(fwd),
                    bwd: p.bwd.unwrap_or(fwd),
                    update,
                    master: plan.quantized && update == Format::Fp16,
                };
                (key, lf)
            })
            .collect();
        Ok(ExecPolicy { quantized: plan.quantized, needs_loss_scaling, nodes })
    }

    /// Routing for one layer of one network; unknown (tag, layer) pairs —
    /// every pair, for the FP32 control — default to FP32.
    pub fn layer(&self, tag: &str, lname: &str) -> LayerFormats {
        self.nodes
            .get(&(tag.to_string(), lname.to_string()))
            .copied()
            .unwrap_or_else(LayerFormats::fp32)
    }

    /// Number of (network, layer) entries parsed from the plan.
    pub fn layer_count(&self) -> usize {
        self.nodes.len()
    }

    /// Iterate the routing table (tests assert against the source plan).
    pub fn entries(&self) -> impl Iterator<Item = (&(String, String), &LayerFormats)> {
        self.nodes.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::{LocalPlanner, PlanRequest, Planner};
    use crate::coordinator::static_phase;

    #[test]
    fn fp32_policy_defaults_everything() {
        let p = ExecPolicy::fp32();
        assert!(!p.quantized && !p.needs_loss_scaling);
        let lf = p.layer("online", "fc0");
        assert_eq!(lf, LayerFormats::fp32());
        assert_eq!(p.layer_count(), 0);
    }

    /// The executor's routing must agree node-for-node with the
    /// coordinator-side `PrecisionPolicy` the plan was derived from —
    /// this is the "plans literally decide layer formats" contract.
    #[test]
    fn from_outcome_matches_precision_policy_node_for_node() {
        // Batch 64 is the Fig 15 all-PL CartPole plan asserted elsewhere
        // (pipeline tests), so the format expectations below are stable.
        let req = PlanRequest::named("dqn_cartpole").unwrap().with_batch(64);
        let outcome = LocalPlanner.plan(&req).unwrap();
        let policy = ExecPolicy::from_outcome(&outcome).unwrap();
        let plan = static_phase(&req.combo, req.batch, req.quantized);
        assert_eq!(policy.needs_loss_scaling, plan.policy.needs_loss_scaling);
        assert!(policy.quantized);
        for node in &plan.dag.nodes {
            let mut parts = node.name.split('/');
            let (Some(tag), Some(lname), Some(kind)) =
                (parts.next(), parts.next(), parts.next())
            else {
                continue;
            };
            let lf = policy.layer(tag, lname);
            let expect = plan.policy.node_format[node.id];
            let got = match kind {
                "fwd" => lf.fwd,
                "act" => lf.act,
                "bwd" => lf.bwd,
                "update" => lf.update,
                _ => continue,
            };
            assert_eq!(got, expect, "node {} routed {:?}, plan says {:?}", node.name, got, expect);
        }
        // cartpole quantized is all-PL: FP16 everywhere, masters armed.
        let lf = policy.layer("online", "fc0");
        assert_eq!(lf.fwd, Format::Fp16);
        assert!(lf.master, "PL update nodes must arm an FP32 master");
        assert!(policy.needs_loss_scaling);
    }

    #[test]
    fn fp32_control_plan_routes_fp32_without_masters() {
        let req = PlanRequest::named("dqn_cartpole").unwrap().with_batch(64).fp32();
        let outcome = LocalPlanner.plan(&req).unwrap();
        let policy = ExecPolicy::from_outcome(&outcome).unwrap();
        assert!(!policy.quantized);
        assert!(!policy.needs_loss_scaling);
        for (_, lf) in policy.entries() {
            assert_eq!(lf.fwd, Format::Fp32);
            assert!(!lf.master);
        }
    }
}
