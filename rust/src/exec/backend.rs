//! Execution backends for the dynamic phase: one trait, two families.
//!
//! A [`Backend`] turns a Table III combo into a trainable
//! [`Agent`]; the coordinator's training loop
//! ([`crate::coordinator::trainer`]) is generic over it:
//!
//! * [`CpuBackend`] — the pure-Rust executor in this module's siblings,
//!   precision-routed by an [`ExecPolicy`] (from a planner outcome or
//!   the FP32 control).  Always compiled; this is what `apdrl train`
//!   and tier-1 CI run.
//! * [`PjrtBackend`] — the lowered-artifact executors (`pjrt` feature),
//!   where formats live inside the compiled computation.

use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::config::ComboConfig;
use crate::coordinator::planner::PlanOutcome;
use crate::drl::a2c::{A2cAgent, A2cConfig};
use crate::drl::ddpg::{DdpgAgent, DdpgConfig};
use crate::drl::dqn::{DqnAgent, DqnConfig};
use crate::drl::ppo::{PpoAgent, PpoConfig};
use crate::drl::Agent;
use crate::graph::{Algo, NetSpec};
use crate::quant::LossScaler;

use super::models::{CpuA2c, CpuDdpg, CpuDqn, CpuPpo};
use super::policy::ExecPolicy;
use super::pool::Pool;

/// An execution backend: builds agents whose network math it executes.
pub trait Backend {
    /// Human-readable tag for reports (`"cpu exec (mixed precision)"`,
    /// `"pjrt (fp32)"`).
    fn describe(&self) -> String;

    /// Build a fresh agent for `combo`, seeded deterministically.
    fn make_agent(&mut self, combo: &ComboConfig, seed: u64) -> Result<Box<dyn Agent>>;

    /// Kernel threads this backend computes with — reporting only; the
    /// CPU kernels are bit-exact at any thread count.
    fn threads(&self) -> usize {
        1
    }
}

fn obs_shape_of(combo: &ComboConfig) -> Vec<usize> {
    match &combo.net {
        NetSpec::Mlp { .. } => vec![combo.obs_dim],
        NetSpec::Conv { in_hw, in_ch, .. } => vec![*in_hw, *in_hw, *in_ch],
    }
}

/// Cross-check a plan against the executor it would configure: build the
/// combo's networks under the plan's policy and assert every
/// `tag/layer/kind` routing entry the *plan* names resolves to an
/// executor layer carrying exactly those formats.  Unlike comparing two
/// policies derived from the same plan, this fails when the executor's
/// network tags or layer names drift from the CDFG's (a new algorithm,
/// a renamed builder tag) or a constructor stops honoring the policy.
///
/// The CDFG's `critic_for_actor` pass is the documented exception: it
/// shares the critic's weights and the executor runs it through the
/// `critic` network (see [`super::models`]), so its entries are skipped.
pub fn verify_routing(combo: &ComboConfig, plan: &PlanOutcome) -> Result<()> {
    let policy = ExecPolicy::from_outcome(plan)?;
    let formats_of = |nets: Vec<(&'static str, &super::layers::Network)>| {
        nets.into_iter().map(|(t, n)| (t, n.layer_formats())).collect::<Vec<_>>()
    };
    let nets = match combo.algo {
        Algo::Dqn => {
            let m = CpuDqn::new(combo, &policy, 0);
            formats_of(m.nets())
        }
        Algo::Ddpg => {
            let m = CpuDdpg::new(combo, &policy, 0);
            formats_of(m.nets())
        }
        Algo::A2c => {
            let m = CpuA2c::new(combo, &policy, 0);
            formats_of(m.nets())
        }
        Algo::Ppo => {
            let m = CpuPpo::new(combo, &policy, 0);
            formats_of(m.nets())
        }
    };
    for ((tag, lname), want) in policy.entries() {
        if tag.as_str() == "critic_for_actor" {
            continue;
        }
        let (_, layers) = nets
            .iter()
            .find(|(t, _)| *t == tag.as_str())
            .ok_or_else(|| {
                anyhow!("plan routes network {tag:?} but the {} executor builds no such net", combo.name)
            })?;
        let got = layers
            .iter()
            .find(|(n, _)| n.as_str() == lname.as_str())
            .map(|(_, f)| *f)
            .ok_or_else(|| anyhow!("plan routes {tag}/{lname} but the executor net has no such layer"))?;
        ensure!(
            got == *want,
            "{tag}/{lname}: executor routed {got:?}, plan says {want:?}"
        );
    }
    Ok(())
}

/// Coordination-schedule overrides (smoke tests and CI shrink the
/// budgets without touching the algorithms).
#[derive(Clone, Copy, Debug, Default)]
struct Tuning {
    train_every: Option<usize>,
    warmup: Option<usize>,
    batch: Option<usize>,
}

/// The pure-Rust CPU backend, precision-routed by an [`ExecPolicy`],
/// with its kernels fanned out over a [`Pool`] (the process-wide
/// `APDRL_THREADS` pool unless [`CpuBackend::with_pool`] rebinds it —
/// thread count changes wall-clock, never results).
pub struct CpuBackend {
    policy: ExecPolicy,
    tuning: Tuning,
    pool: Arc<Pool>,
}

impl CpuBackend {
    /// The FP32 control backend (no plan needed).
    pub fn fp32() -> CpuBackend {
        CpuBackend::from_policy(ExecPolicy::fp32())
    }

    pub fn from_policy(policy: ExecPolicy) -> CpuBackend {
        CpuBackend { policy, tuning: Tuning::default(), pool: Pool::global() }
    }

    /// Run the executor's kernels on an explicit pool (tests pin thread
    /// counts; `apdrl train --threads N` routes through here).
    pub fn with_pool(mut self, pool: Arc<Pool>) -> CpuBackend {
        self.pool = pool;
        self
    }

    /// Backend executing the precision routing of a solved plan — this
    /// is the planner → executor hand-off of `apdrl train`.
    pub fn from_outcome(plan: &PlanOutcome) -> Result<CpuBackend> {
        Ok(CpuBackend::from_policy(ExecPolicy::from_outcome(plan)?))
    }

    pub fn policy(&self) -> &ExecPolicy {
        &self.policy
    }

    /// Train every `n` env steps instead of the per-combo default.
    /// Off-policy agents (DQN/DDPG) only — on-policy agents train once
    /// per full rollout and ignore this.
    pub fn with_train_every(mut self, n: usize) -> CpuBackend {
        self.tuning.train_every = Some(n);
        self
    }

    /// Replay warmup override.  Off-policy agents (DQN/DDPG) only.
    pub fn with_warmup(mut self, n: usize) -> CpuBackend {
        self.tuning.warmup = Some(n);
        self
    }

    /// Batch (off-policy) / rollout-horizon (on-policy) override.
    pub fn with_batch(mut self, n: usize) -> CpuBackend {
        self.tuning.batch = Some(n);
        self
    }

    fn scaler(&self) -> LossScaler {
        if self.policy.needs_loss_scaling {
            LossScaler::default()
        } else {
            LossScaler::disabled()
        }
    }
}

impl Backend for CpuBackend {
    fn describe(&self) -> String {
        if self.policy.quantized {
            "cpu exec (mixed precision)".to_string()
        } else {
            "cpu exec (fp32)".to_string()
        }
    }

    fn make_agent(&mut self, combo: &ComboConfig, seed: u64) -> Result<Box<dyn Agent>> {
        let batch = self.tuning.batch.unwrap_or(combo.batch);
        let pool = self.pool.clone();
        Ok(match combo.algo {
            Algo::Dqn => {
                let mut cfg = DqnConfig::for_combo(batch, obs_shape_of(combo), combo.act_dim);
                if let Some(n) = self.tuning.train_every {
                    cfg.train_every = n;
                }
                if let Some(n) = self.tuning.warmup {
                    cfg.warmup = n;
                }
                Box::new(DqnAgent::from_parts(
                    cfg,
                    CpuDqn::new_pooled(combo, &self.policy, seed, pool),
                    self.scaler(),
                ))
            }
            Algo::Ddpg => {
                let mut cfg = DdpgConfig::for_combo(batch, combo.obs_dim, combo.act_dim);
                if let Some(n) = self.tuning.train_every {
                    cfg.train_every = n;
                }
                if let Some(n) = self.tuning.warmup {
                    cfg.warmup = n;
                }
                Box::new(DdpgAgent::from_parts(
                    cfg,
                    CpuDdpg::new_pooled(combo, &self.policy, seed, pool),
                    self.scaler(),
                ))
            }
            Algo::A2c => {
                let cfg = A2cConfig::for_combo(batch, combo.obs_dim, combo.act_dim);
                Box::new(A2cAgent::from_parts(
                    cfg,
                    CpuA2c::new_pooled(combo, &self.policy, seed, pool),
                    self.scaler(),
                ))
            }
            Algo::Ppo => {
                let cfg = PpoConfig::for_combo(batch, obs_shape_of(combo), combo.act_dim);
                Box::new(PpoAgent::from_parts(
                    cfg,
                    CpuPpo::new_pooled(combo, &self.policy, seed, pool),
                    self.scaler(),
                ))
            }
        })
    }

    fn threads(&self) -> usize {
        self.pool.threads()
    }
}

/// The PJRT backend: agents over lowered artifacts in one precision
/// `mode` ("fp32" | "mixed" | "bf16").  Borrows the runtime so several
/// backends (one per mode) can share the loaded artifact cache.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend<'r> {
    runtime: &'r mut crate::runtime::Runtime,
    mode: String,
}

#[cfg(feature = "pjrt")]
impl<'r> PjrtBackend<'r> {
    pub fn new(runtime: &'r mut crate::runtime::Runtime, mode: &str) -> PjrtBackend<'r> {
        PjrtBackend { runtime, mode: mode.to_string() }
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend<'_> {
    fn describe(&self) -> String {
        format!("pjrt ({})", self.mode)
    }

    fn make_agent(&mut self, combo: &ComboConfig, seed: u64) -> Result<Box<dyn Agent>> {
        use crate::drl::pjrt;
        Ok(match combo.algo {
            Algo::Dqn => {
                let cfg =
                    DqnConfig::for_combo(combo.batch, obs_shape_of(combo), combo.act_dim);
                Box::new(pjrt::dqn_agent(self.runtime, combo.name, &self.mode, cfg, seed)?)
            }
            Algo::Ddpg => {
                let cfg = DdpgConfig::for_combo(combo.batch, combo.obs_dim, combo.act_dim);
                Box::new(pjrt::ddpg_agent(self.runtime, combo.name, &self.mode, cfg, seed)?)
            }
            Algo::A2c => {
                let cfg = A2cConfig::for_combo(combo.batch, combo.obs_dim, combo.act_dim);
                Box::new(pjrt::a2c_agent(self.runtime, combo.name, &self.mode, cfg, seed)?)
            }
            Algo::Ppo => {
                let cfg =
                    PpoConfig::for_combo(combo.batch, obs_shape_of(combo), combo.act_dim);
                Box::new(pjrt::ppo_agent(self.runtime, combo.name, &self.mode, cfg, seed)?)
            }
        })
    }
}
