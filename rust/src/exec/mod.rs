//! Pure-Rust CPU execution backend — the dynamic phase with no PJRT.
//!
//! The paper's headline is not the partitioner alone but that the
//! FP32/FP16/BF16-*coordinated* training loop converges (Fig 7 right,
//! Alg. 1, Table II).  This subsystem executes that loop on the host
//! CPU, bit-faithfully emulating the coordinated formats through
//! [`crate::quant::formats`]:
//!
//! * [`tensor`] — dense f32 tensors + the three GEMM variants the layer
//!   math needs (cache-blocked, packed, row-parallel — bit-identical to
//!   the `*_naive` references at any thread count), with in-place
//!   format rounding through the vectorized
//!   [`crate::quant::formats::round_slice`] fast path;
//! * [`pool`] — the reusable scoped worker pool the kernels fan out
//!   over, sized by `APDRL_THREADS` (thread count never changes
//!   numerics, only wall-clock);
//! * [`layers`] — dense/conv layers (im2col) with cached forward,
//!   hand-written reverse-mode backward, per-layer [`LayerFormats`]
//!   hooks and FP32 master copies where the policy arms them;
//! * [`adam`] — Adam with loss-scale unscaling, `found_inf` overflow
//!   detection (skip-on-overflow) and master-weight accumulation;
//! * [`policy`] — [`ExecPolicy`]: a solved [`PlanOutcome`]'s per-node
//!   formats folded into per-(network, layer) routing, so the partition
//!   plan literally decides which layers train in BF16/FP16/FP32;
//! * [`models`] — CPU implementations of the four per-algorithm compute
//!   traits ([`crate::drl::compute`]);
//! * [`backend`] — the [`Backend`] trait gluing it to the trainer, with
//!   [`CpuBackend`] (always) and `PjrtBackend` (`pjrt` feature).
//!
//! [`PlanOutcome`]: crate::coordinator::planner::PlanOutcome

pub mod adam;
pub mod backend;
pub mod layers;
pub mod models;
pub mod policy;
pub mod pool;
pub mod tensor;

pub use adam::Adam;
pub use backend::{verify_routing, Backend, CpuBackend};
#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use layers::{Act, Network, Param};
pub use models::{CpuA2c, CpuDdpg, CpuDqn, CpuPpo};
pub use policy::{ExecPolicy, LayerFormats};
pub use pool::Pool;
pub use tensor::Tensor;
