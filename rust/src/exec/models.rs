//! CPU implementations of the per-algorithm compute traits
//! ([`crate::drl::compute`]): the paper's dynamic phase with no PJRT.
//!
//! Each model owns the same networks the CDFG describes for its
//! algorithm (`graph::builder`): DQN's online/target pair, DDPG's four
//! networks, A2C/PPO's actor + value nets.  Layers are precision-routed
//! by the [`ExecPolicy`] tags matching the CDFG node names — `online`,
//! `target`, `actor`, `critic`, `t_actor`, `t_critic`, `value` — so the
//! partition plan decides each network's formats.  (The CDFG's separate
//! `critic_for_actor` pass shares the critic's weights; the executor
//! runs it through the `critic` network and therefore the `critic`
//! routing.)
//!
//! Losses are scaled by the FSM's current scale before backprop; the
//! [`Adam`] optimizers detect scaled-gradient overflow (`found_inf`) and
//! skip the update, completing the Fig 9 loop.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::config::ComboConfig;
use crate::drl::compute::{A2cCompute, ComputeBackend, DdpgCompute, DqnCompute, PpoCompute, TrainOut};
use crate::drl::replay::Batch;
use crate::drl::rollout::RolloutBatch;
use crate::graph::{critic_spec, value_spec};
use crate::hw::Format;
use crate::util::json::{hex_f32s, parse_hex_f32s, Json};
use crate::util::Rng;

use super::adam::Adam;
use super::layers::{Act, Network, Param};
use super::policy::ExecPolicy;
use super::pool::Pool;
use super::tensor::Tensor;

fn batch_tensor(data: &[f32], bs: usize) -> Tensor {
    Tensor::from_vec(data.to_vec(), &[bs, data.len() / bs])
}

fn concat_cols(a: &Tensor, b: &Tensor) -> Tensor {
    let bs = a.rows();
    assert_eq!(bs, b.rows());
    let (ca, cb) = (a.cols(), b.cols());
    let mut data = Vec::with_capacity(bs * (ca + cb));
    for i in 0..bs {
        data.extend_from_slice(&a.data[i * ca..(i + 1) * ca]);
        data.extend_from_slice(&b.data[i * cb..(i + 1) * cb]);
    }
    Tensor::from_vec(data, &[bs, ca + cb])
}

// ---------------------------------------------------------------- DQN --

/// DQN on the CPU executor: online + target Q-nets, MSE TD loss (Eq. 1).
pub struct CpuDqn {
    online: Network,
    target: Network,
    opt: Adam,
    gamma: f32,
    policy: ExecPolicy,
}

impl CpuDqn {
    pub fn new(combo: &ComboConfig, policy: &ExecPolicy, seed: u64) -> CpuDqn {
        Self::new_pooled(combo, policy, seed, Pool::global())
    }

    /// Same, with the networks' kernels bound to an explicit pool.
    pub fn new_pooled(
        combo: &ComboConfig,
        policy: &ExecPolicy,
        seed: u64,
        pool: Arc<Pool>,
    ) -> CpuDqn {
        let mut rng = Rng::new(seed ^ 0xD09);
        let online = Network::from_spec(&combo.net, Act::None, policy, "online", &mut rng)
            .with_pool(pool.clone());
        let mut target = Network::from_spec(&combo.net, Act::None, policy, "target", &mut rng)
            .with_pool(pool);
        target.copy_weights_from(&online);
        CpuDqn { online, target, opt: Adam::new(1e-3), gamma: 0.99, policy: policy.clone() }
    }

    /// `(CDFG tag, network)` pairs — routing assertions inspect these.
    pub fn nets(&self) -> Vec<(&'static str, &Network)> {
        vec![("online", &self.online), ("target", &self.target)]
    }
}

impl ComputeBackend for CpuDqn {
    fn exec_policy(&self) -> Option<&ExecPolicy> {
        Some(&self.policy)
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![
            ("online", self.online.weights_to_json()),
            ("target", self.target.weights_to_json()),
            ("opt", self.opt.to_json()),
        ]))
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.online.restore_weights(state.req("online")?)?;
        self.target.restore_weights(state.req("target")?)?;
        self.opt = Adam::from_json(state.req("opt")?)?;
        Ok(())
    }
}

impl DqnCompute for CpuDqn {
    fn qvalues(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<f32>> {
        // One GEMM per layer for all lanes; rows are independent in
        // every kernel, so lanes == 1 matches the old scalar forward
        // bit-for-bit.
        Ok(self.online.infer(&batch_tensor(obs, lanes)).data)
    }

    fn train(&mut self, batch: &Batch, loss_scale: f32) -> Result<TrainOut> {
        let bs = batch.size;
        let obs = batch_tensor(&batch.obs, bs);
        let next = batch_tensor(&batch.next_obs, bs);
        let q = self.online.forward(&obs);
        let qn = self.target.infer(&next);
        let na = q.cols();
        let mut g = Tensor::zeros(&[bs, na]);
        let mut loss = 0.0f32;
        for i in 0..bs {
            let best =
                qn.data[i * na..(i + 1) * na].iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let y = batch.rewards[i] + self.gamma * (1.0 - batch.dones[i]) * best;
            let a = batch.actions_i32[i] as usize;
            let diff = q.data[i * na + a] - y;
            loss += diff * diff;
            g.data[i * na + a] = 2.0 * diff / bs as f32 * loss_scale;
        }
        loss /= bs as f32;
        self.online.zero_grads();
        self.online.backward(&g, true);
        let found_inf = self.opt.step(self.online.params_mut(), loss_scale);
        Ok(TrainOut { loss, found_inf })
    }

    fn sync_target(&mut self) -> Result<()> {
        self.target.copy_weights_from(&self.online);
        Ok(())
    }
}

// ---------------------------------------------------------------- A2C --

/// A2C on the CPU executor: Gaussian policy (state-independent log-std)
/// + value net, entropy-regularized.
pub struct CpuA2c {
    pi: Network,
    vf: Network,
    log_std: Param,
    opt: Adam,
    ent_coef: f32,
    vf_coef: f32,
    policy: ExecPolicy,
}

impl CpuA2c {
    pub fn new(combo: &ComboConfig, policy: &ExecPolicy, seed: u64) -> CpuA2c {
        Self::new_pooled(combo, policy, seed, Pool::global())
    }

    /// Same, with the networks' kernels bound to an explicit pool.
    pub fn new_pooled(
        combo: &ComboConfig,
        policy: &ExecPolicy,
        seed: u64,
        pool: Arc<Pool>,
    ) -> CpuA2c {
        let mut rng = Rng::new(seed ^ 0xA2C);
        let pi = Network::from_spec(&combo.net, Act::None, policy, "actor", &mut rng)
            .with_pool(pool.clone());
        let vf = Network::from_spec(&value_spec(&combo.net), Act::None, policy, "value", &mut rng)
            .with_pool(pool);
        // log_std is a coordinator-resident FP32 parameter (no CDFG node).
        let log_std = Param::new(vec![0.0; combo.act_dim], &[combo.act_dim], Format::Fp32, false);
        CpuA2c {
            pi,
            vf,
            log_std,
            opt: Adam::new(7e-4),
            ent_coef: 0.01,
            vf_coef: 0.5,
            policy: policy.clone(),
        }
    }

    pub fn nets(&self) -> Vec<(&'static str, &Network)> {
        vec![("actor", &self.pi), ("value", &self.vf)]
    }
}

impl ComputeBackend for CpuA2c {
    fn exec_policy(&self) -> Option<&ExecPolicy> {
        Some(&self.policy)
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![
            ("pi", self.pi.weights_to_json()),
            ("vf", self.vf.weights_to_json()),
            ("log_std", Json::Str(hex_f32s(&self.log_std.value.data))),
            ("opt", self.opt.to_json()),
        ]))
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.pi.restore_weights(state.req("pi")?)?;
        self.vf.restore_weights(state.req("vf")?)?;
        let ls = parse_hex_f32s(state.req_str("log_std")?)?;
        anyhow::ensure!(ls.len() == self.log_std.elems(), "log_std length mismatch");
        self.log_std.value.data = ls;
        self.opt = Adam::from_json(state.req("opt")?)?;
        Ok(())
    }
}

impl A2cCompute for CpuA2c {
    fn policy(&mut self, obs: &[f32], lanes: usize) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let x = batch_tensor(obs, lanes);
        let means = self.pi.infer(&x).data;
        let values = self.vf.infer(&x).data;
        Ok((means, self.log_std.value.data.clone(), values))
    }

    fn train(&mut self, batch: &RolloutBatch, loss_scale: f32) -> Result<TrainOut> {
        let bs = batch.size;
        let bsf = bs as f32;
        let obs = batch_tensor(&batch.obs, bs);
        let mean = self.pi.forward(&obs);
        let v = self.vf.forward(&obs);
        let ad = mean.cols();
        let std: Vec<f32> = self.log_std.value.data.iter().map(|l| l.exp()).collect();
        let mut dmean = Tensor::zeros(&[bs, ad]);
        let mut dlog_std = vec![0.0f32; ad];
        let mut dv = Tensor::zeros(&[bs, 1]);
        let (mut ploss, mut vloss) = (0.0f32, 0.0f32);
        const LOG_2PI: f32 = 1.837_877_1;
        for i in 0..bs {
            let adv = batch.advantages[i];
            for j in 0..ad {
                let a = batch.actions_f32[i * ad + j];
                let z = (a - mean.data[i * ad + j]) / std[j];
                ploss += adv * (0.5 * z * z + self.log_std.value.data[j] + 0.5 * LOG_2PI) / bsf;
                dmean.data[i * ad + j] = -adv * z / std[j] / bsf * loss_scale;
                dlog_std[j] += -adv * (z * z - 1.0) / bsf * loss_scale;
            }
            let diff = v.data[i] - batch.returns[i];
            vloss += diff * diff / bsf;
            dv.data[i] = self.vf_coef * 2.0 * diff / bsf * loss_scale;
        }
        // Gaussian entropy: Σ_j log_std_j + const; maximized via -coef·H.
        let entropy: f32 =
            self.log_std.value.data.iter().sum::<f32>() + 0.5 * ad as f32 * (LOG_2PI + 1.0);
        for d in dlog_std.iter_mut() {
            *d -= self.ent_coef * loss_scale;
        }
        let loss = ploss + self.vf_coef * vloss - self.ent_coef * entropy;
        self.pi.zero_grads();
        self.pi.backward(&dmean, true);
        self.vf.zero_grads();
        self.vf.backward(&dv, true);
        self.log_std.grad.copy_from_slice(&dlog_std);
        let mut params = self.pi.params_mut();
        params.push(&mut self.log_std);
        params.extend(self.vf.params_mut());
        let found_inf = self.opt.step(params, loss_scale);
        Ok(TrainOut { loss, found_inf })
    }
}

// --------------------------------------------------------------- DDPG --

/// DDPG on the CPU executor: tanh actor + Q critic, soft targets.
pub struct CpuDdpg {
    actor: Network,
    critic: Network,
    t_actor: Network,
    t_critic: Network,
    opt_a: Adam,
    opt_c: Adam,
    gamma: f32,
    tau: f32,
    policy: ExecPolicy,
}

impl CpuDdpg {
    pub fn new(combo: &ComboConfig, policy: &ExecPolicy, seed: u64) -> CpuDdpg {
        Self::new_pooled(combo, policy, seed, Pool::global())
    }

    /// Same, with the networks' kernels bound to an explicit pool.
    pub fn new_pooled(
        combo: &ComboConfig,
        policy: &ExecPolicy,
        seed: u64,
        pool: Arc<Pool>,
    ) -> CpuDdpg {
        let mut rng = Rng::new(seed ^ 0xDD96);
        let cnet = critic_spec(&combo.net, combo.obs_dim, combo.act_dim);
        let actor = Network::from_spec(&combo.net, Act::Tanh, policy, "actor", &mut rng)
            .with_pool(pool.clone());
        let critic = Network::from_spec(&cnet, Act::None, policy, "critic", &mut rng)
            .with_pool(pool.clone());
        let mut t_actor = Network::from_spec(&combo.net, Act::Tanh, policy, "t_actor", &mut rng)
            .with_pool(pool.clone());
        let mut t_critic = Network::from_spec(&cnet, Act::None, policy, "t_critic", &mut rng)
            .with_pool(pool);
        t_actor.copy_weights_from(&actor);
        t_critic.copy_weights_from(&critic);
        CpuDdpg {
            actor,
            critic,
            t_actor,
            t_critic,
            opt_a: Adam::new(1e-4),
            opt_c: Adam::new(1e-3),
            gamma: 0.99,
            tau: 0.005,
            policy: policy.clone(),
        }
    }

    pub fn nets(&self) -> Vec<(&'static str, &Network)> {
        vec![
            ("actor", &self.actor),
            ("critic", &self.critic),
            ("t_actor", &self.t_actor),
            ("t_critic", &self.t_critic),
        ]
    }
}

impl ComputeBackend for CpuDdpg {
    fn exec_policy(&self) -> Option<&ExecPolicy> {
        Some(&self.policy)
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![
            ("actor", self.actor.weights_to_json()),
            ("critic", self.critic.weights_to_json()),
            ("t_actor", self.t_actor.weights_to_json()),
            ("t_critic", self.t_critic.weights_to_json()),
            ("opt_a", self.opt_a.to_json()),
            ("opt_c", self.opt_c.to_json()),
        ]))
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.actor.restore_weights(state.req("actor")?)?;
        self.critic.restore_weights(state.req("critic")?)?;
        self.t_actor.restore_weights(state.req("t_actor")?)?;
        self.t_critic.restore_weights(state.req("t_critic")?)?;
        self.opt_a = Adam::from_json(state.req("opt_a")?)?;
        self.opt_c = Adam::from_json(state.req("opt_c")?)?;
        Ok(())
    }
}

impl DdpgCompute for CpuDdpg {
    fn action(&mut self, obs: &[f32], lanes: usize) -> Result<Vec<f32>> {
        Ok(self.actor.infer(&batch_tensor(obs, lanes)).data)
    }

    fn train(&mut self, batch: &Batch, loss_scale: f32) -> Result<TrainOut> {
        let bs = batch.size;
        let bsf = bs as f32;
        let obs = batch_tensor(&batch.obs, bs);
        let next = batch_tensor(&batch.next_obs, bs);
        let act = batch_tensor(&batch.actions_f32, bs);
        // Critic update: y = r + γ(1−d)·Q'(s', µ'(s')).
        let a2 = self.t_actor.infer(&next);
        let q2 = self.t_critic.infer(&concat_cols(&next, &a2));
        let q = self.critic.forward(&concat_cols(&obs, &act));
        let mut dq = Tensor::zeros(&[bs, 1]);
        let mut closs = 0.0f32;
        for i in 0..bs {
            let y = batch.rewards[i] + self.gamma * (1.0 - batch.dones[i]) * q2.data[i];
            let diff = q.data[i] - y;
            closs += diff * diff / bsf;
            dq.data[i] = 2.0 * diff / bsf * loss_scale;
        }
        self.critic.zero_grads();
        self.critic.backward(&dq, true);
        // Actor gradients: maximize Q(s, µ(s)) — backprop through the
        // critic (pre-update weights, fused-step semantics) to the
        // action input, then through the actor.  The critic's own grads
        // are not accumulated by this second pass.
        let a = self.actor.forward(&obs);
        let _qa = self.critic.forward(&concat_cols(&obs, &a));
        let seed = Tensor::from_vec(vec![-loss_scale / bsf; bs], &[bs, 1]);
        let dinput = self.critic.backward(&seed, false);
        let od = obs.cols();
        let ad = a.cols();
        let mut da = Tensor::zeros(&[bs, ad]);
        for i in 0..bs {
            da.data[i * ad..(i + 1) * ad]
                .copy_from_slice(&dinput.data[i * (od + ad) + od..(i + 1) * (od + ad)]);
        }
        self.actor.zero_grads();
        self.actor.backward(&da, true);
        // All-or-nothing conditional skip: overflow in *either* network's
        // scaled gradients skips the whole fused step (no partial actor
        // update while the critic is skipped, and vice versa).
        let found_inf =
            self.critic.has_non_finite_grads() || self.actor.has_non_finite_grads();
        if !found_inf {
            self.opt_c.step(self.critic.params_mut(), loss_scale);
            self.opt_a.step(self.actor.params_mut(), loss_scale);
            self.t_actor.soft_update_from(&self.actor, self.tau);
            self.t_critic.soft_update_from(&self.critic, self.tau);
        }
        Ok(TrainOut { loss: closs, found_inf })
    }
}

// ---------------------------------------------------------------- PPO --

/// PPO on the CPU executor: discrete actor + value net, clipped
/// surrogate with entropy bonus; the agent drives the epoch loop.
pub struct CpuPpo {
    pi: Network,
    vf: Network,
    opt: Adam,
    clip: f32,
    ent_coef: f32,
    vf_coef: f32,
    policy: ExecPolicy,
}

impl CpuPpo {
    pub fn new(combo: &ComboConfig, policy: &ExecPolicy, seed: u64) -> CpuPpo {
        Self::new_pooled(combo, policy, seed, Pool::global())
    }

    /// Same, with the networks' kernels bound to an explicit pool.
    pub fn new_pooled(
        combo: &ComboConfig,
        policy: &ExecPolicy,
        seed: u64,
        pool: Arc<Pool>,
    ) -> CpuPpo {
        let mut rng = Rng::new(seed ^ 0x990);
        let pi = Network::from_spec(&combo.net, Act::None, policy, "actor", &mut rng)
            .with_pool(pool.clone());
        let vf = Network::from_spec(&value_spec(&combo.net), Act::None, policy, "value", &mut rng)
            .with_pool(pool);
        CpuPpo {
            pi,
            vf,
            opt: Adam::new(3e-4),
            clip: 0.2,
            ent_coef: 0.01,
            vf_coef: 0.5,
            policy: policy.clone(),
        }
    }

    pub fn nets(&self) -> Vec<(&'static str, &Network)> {
        vec![("actor", &self.pi), ("value", &self.vf)]
    }
}

impl ComputeBackend for CpuPpo {
    fn exec_policy(&self) -> Option<&ExecPolicy> {
        Some(&self.policy)
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![
            ("pi", self.pi.weights_to_json()),
            ("vf", self.vf.weights_to_json()),
            ("opt", self.opt.to_json()),
        ]))
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.pi.restore_weights(state.req("pi")?)?;
        self.vf.restore_weights(state.req("vf")?)?;
        self.opt = Adam::from_json(state.req("opt")?)?;
        Ok(())
    }
}

impl PpoCompute for CpuPpo {
    fn policy(&mut self, obs: &[f32], lanes: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let x = batch_tensor(obs, lanes);
        let logits = self.pi.infer(&x).data;
        let values = self.vf.infer(&x).data;
        Ok((logits, values))
    }

    fn train(&mut self, batch: &RolloutBatch, loss_scale: f32) -> Result<TrainOut> {
        let bs = batch.size;
        let bsf = bs as f32;
        let obs = batch_tensor(&batch.obs, bs);
        let logits = self.pi.forward(&obs);
        let v = self.vf.forward(&obs);
        let na = logits.cols();
        let mut dlogits = Tensor::zeros(&[bs, na]);
        let mut dv = Tensor::zeros(&[bs, 1]);
        let (mut ploss, mut vloss, mut ent) = (0.0f32, 0.0f32, 0.0f32);
        for i in 0..bs {
            let row = &logits.data[i * na..(i + 1) * na];
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let logz = row.iter().map(|l| (l - max).exp()).sum::<f32>().ln() + max;
            let logp: Vec<f32> = row.iter().map(|l| l - logz).collect();
            let p: Vec<f32> = logp.iter().map(|l| l.exp()).collect();
            let h: f32 = logp.iter().zip(&p).map(|(&lp, &pp)| -pp * lp).sum();
            ent += h / bsf;
            let a = batch.actions_i32[i] as usize;
            let adv = batch.advantages[i];
            let ratio = (logp[a] - batch.logp_old[i]).exp();
            let s1 = ratio * adv;
            let s2 = ratio.clamp(1.0 - self.clip, 1.0 + self.clip) * adv;
            ploss += -s1.min(s2) / bsf;
            let active = s1 <= s2;
            for k in 0..na {
                let onehot = if k == a { 1.0 } else { 0.0 };
                let mut d = self.ent_coef * p[k] * (logp[k] + h);
                if active {
                    d += -adv * ratio * (onehot - p[k]);
                }
                dlogits.data[i * na + k] = d / bsf * loss_scale;
            }
            let diff = v.data[i] - batch.returns[i];
            vloss += diff * diff / bsf;
            dv.data[i] = self.vf_coef * 2.0 * diff / bsf * loss_scale;
        }
        let loss = ploss + self.vf_coef * vloss - self.ent_coef * ent;
        self.pi.zero_grads();
        self.pi.backward(&dlogits, true);
        self.vf.zero_grads();
        self.vf.backward(&dv, true);
        let mut params = self.pi.params_mut();
        params.extend(self.vf.params_mut());
        let found_inf = self.opt.step(params, loss_scale);
        Ok(TrainOut { loss, found_inf })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::combo;
    use crate::drl::replay::{ReplayBuffer, StoredAction};

    fn fp32_policy() -> ExecPolicy {
        ExecPolicy::fp32()
    }

    #[test]
    fn dqn_train_reduces_td_loss_on_fixed_batch() {
        let c = combo("dqn_cartpole");
        let policy = fp32_policy();
        let mut model = CpuDqn::new(&c, &policy, 7);
        let mut rb = ReplayBuffer::new(64, c.obs_dim);
        let mut rng = Rng::new(3);
        for _ in 0..32 {
            let o: Vec<f32> = (0..c.obs_dim).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
            let o2: Vec<f32> = (0..c.obs_dim).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
            rb.push(&o, StoredAction::Discrete(rng.below(2) as i32), 1.0, &o2, false);
        }
        let batch = rb.sample(32, &mut rng);
        let first = model.train(&batch, 1.0).unwrap();
        assert!(!first.found_inf);
        let mut last = first.loss;
        for _ in 0..30 {
            last = model.train(&batch, 1.0).unwrap().loss;
        }
        assert!(
            last < first.loss,
            "TD loss must fall on a fixed batch: {} -> {last}",
            first.loss
        );
    }

    #[test]
    fn dqn_target_sync_makes_nets_agree() {
        let c = combo("dqn_cartpole");
        let mut model = CpuDqn::new(&c, &fp32_policy(), 9);
        let mut rng = Rng::new(4);
        let mut rb = ReplayBuffer::new(32, c.obs_dim);
        for _ in 0..16 {
            let o: Vec<f32> = (0..c.obs_dim).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
            rb.push(&o, StoredAction::Discrete(0), 0.5, &o, false);
        }
        let batch = rb.sample(16, &mut rng);
        for _ in 0..3 {
            model.train(&batch, 1.0).unwrap();
        }
        let obs = vec![0.1, -0.2, 0.3, 0.0];
        let q_online = model.qvalues(&obs, 1).unwrap();
        let q_target = model.target.infer(&batch_tensor(&obs, 1)).data;
        assert_ne!(q_online, q_target, "training must move online away from target");
        model.sync_target().unwrap();
        let q_target = model.target.infer(&batch_tensor(&obs, 1)).data;
        assert_eq!(q_online, q_target, "sync must align target with online");
    }

    #[test]
    fn ddpg_actions_bounded_and_critic_loss_falls() {
        let c = combo("ddpg_mntncar");
        let mut model = CpuDdpg::new(&c, &fp32_policy(), 11);
        let mut rng = Rng::new(5);
        let a = model.action(&[0.3, -0.1], 1).unwrap();
        assert_eq!(a.len(), c.act_dim);
        assert!(a.iter().all(|x| x.abs() <= 1.0), "tanh head must bound actions");
        let mut rb = ReplayBuffer::new(64, c.obs_dim);
        for _ in 0..32 {
            let o: Vec<f32> = (0..c.obs_dim).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
            let act: Vec<f32> =
                (0..c.act_dim).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
            rb.push(&o, StoredAction::Continuous(act), 0.1, &o, false);
        }
        let batch = rb.sample(32, &mut rng);
        let first = model.train(&batch, 1.0).unwrap();
        let mut last = first.loss;
        for _ in 0..20 {
            last = model.train(&batch, 1.0).unwrap().loss;
        }
        assert!(last < first.loss, "critic loss must fall: {} -> {last}", first.loss);
    }

    #[test]
    fn batched_inference_rows_match_batch1_calls() {
        // The N-wide actor forward must reproduce each lane's batch-1
        // result bit-for-bit (rows are independent in every kernel) —
        // the compute-level half of the --actors 1 bit-identity story.
        let c = combo("dqn_cartpole");
        let mut model = CpuDqn::new(&c, &fp32_policy(), 21);
        let mut rng = Rng::new(6);
        let lanes = 5;
        let obs: Vec<f32> =
            (0..lanes * c.obs_dim).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        let q = model.qvalues(&obs, lanes).unwrap();
        let na = q.len() / lanes;
        assert_eq!(na, 2);
        for l in 0..lanes {
            let ql = model.qvalues(&obs[l * c.obs_dim..(l + 1) * c.obs_dim], 1).unwrap();
            assert_eq!(&q[l * na..(l + 1) * na], &ql[..], "lane {l}");
        }
    }

    #[test]
    fn fp16_policy_arms_masters_and_scaled_training_survives() {
        // All-FP16 routing (what a quantized all-PL cartpole plan gives):
        // masters armed, huge loss scale overflows fp16 grads -> found_inf.
        use super::super::policy::LayerFormats;
        use crate::graph::NetSpec;
        let fmt = LayerFormats {
            fwd: Format::Fp16,
            act: Format::Fp16,
            bwd: Format::Fp16,
            update: Format::Fp16,
            master: true,
        };
        let mut rng = Rng::new(2);
        let mut net =
            Network::from_spec_uniform(&NetSpec::mlp(&[4, 8, 2]), Act::None, fmt, &mut rng);
        for layer in &net.layers {
            assert!(layer.w.master.is_some(), "FP16 layers must carry FP32 masters");
        }
        let x = Tensor::from_vec(vec![0.5, -0.5, 0.25, 0.0], &[2, 4]);
        net.forward(&x);
        let g = Tensor::from_vec(vec![1.0, -1.0, 0.5, 0.25], &[2, 2]);
        net.zero_grads();
        net.backward(&g, true);
        let mut opt = Adam::new(1e-3);
        assert!(!opt.step(net.params_mut(), 1.0));
        // An absurd scaled loss overflows the rounded fp16 gradients
        // (fp16 max finite is 65504, so 1e6 rounds straight to inf).
        net.forward(&x);
        let big = Tensor::from_vec(vec![1e6, -1e6, 5e5, 2.5e5], &[2, 2]);
        net.zero_grads();
        net.backward(&big, true);
        let any_inf = net.params_mut().iter().any(|p| p.grad.iter().any(|v| !v.is_finite()));
        assert!(any_inf, "fp16 rounding must overflow to inf at huge scale");
        assert!(opt.step(net.params_mut(), 65536.0), "overflow must report found_inf");
    }
}
