//! Artifact manifest: the I/O contract emitted by `python/compile/aot.py`
//! (positional tensor specs per artifact), parsed with the in-repo JSON
//! parser.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Tensor dtype in an artifact signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// Shape + dtype of one positional input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Raw meta object (kind, algo, mode, batch, param_shapes, ...).
    pub meta: Json,
}

impl ArtifactSpec {
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str())
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }

    /// Number of leading inputs that are parameters/opt-state (everything
    /// before the batch arrays), derived from param_shapes when present.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        if let Some(arr) = self.meta.get("param_shapes").and_then(|v| v.as_arr()) {
            for sh in arr {
                if let Some(dims) = sh.as_arr() {
                    out.push(dims.iter().filter_map(|d| d.as_usize()).collect());
                }
            }
        }
        out
    }
}

/// Parsed manifest.json.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    let arr = v.as_arr().ok_or_else(|| anyhow!("specs not an array"))?;
    arr.iter()
        .map(|e| {
            let shape = e
                .get("shape")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = Dtype::parse(
                e.get("dtype").and_then(|d| d.as_str()).ok_or_else(|| anyhow!("missing dtype"))?,
            )?;
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let arts = root
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest missing artifacts object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in arts {
            let file = dir.join(
                entry
                    .get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("artifact {name}: missing file"))?,
            );
            let spec = ArtifactSpec {
                name: name.clone(),
                file,
                inputs: parse_specs(entry.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
                outputs: parse_specs(entry.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
                meta: entry.get("meta").cloned().unwrap_or(Json::Null),
            };
            artifacts.insert(name.clone(), spec);
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest ({} known)", self.artifacts.len()))
    }

    /// Artifact name for a (combo, mode, kind) triple, e.g.
    /// ("dqn_cartpole", "mixed", "train").
    pub fn artifact_name(combo: &str, mode: &str, kind: &str) -> String {
        format!("{combo}_{mode}_{kind}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        Manifest::load(dir).ok()
    }

    #[test]
    fn loads_manifest_when_built() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        assert!(m.artifacts.len() >= 40, "expected 42 artifacts, got {}", m.artifacts.len());
        let a = m.get("dqn_cartpole_mixed_train").unwrap();
        assert_eq!(a.meta_str("kind"), Some("train"));
        assert_eq!(a.meta_usize("batch"), Some(64));
        // last input is the loss_scale scalar; last output found_inf
        assert_eq!(a.inputs.last().unwrap().shape, Vec::<usize>::new());
        assert_eq!(a.outputs.last().unwrap().shape, Vec::<usize>::new());
        assert!(a.file.exists());
        // param shapes mirror the python-side convention
        let ps = a.param_shapes();
        assert_eq!(ps[0], vec![4, 64]);
        assert_eq!(ps[1], vec![64]);
    }

    #[test]
    fn artifact_name_format() {
        assert_eq!(
            Manifest::artifact_name("ddpg_lunar", "fp32", "act"),
            "ddpg_lunar_fp32_act"
        );
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load("/nonexistent/dir").is_err());
    }
}
