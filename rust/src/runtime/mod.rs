//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) and
//! execute them from the coordinator's hot path.
//!
//! Flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  HLO *text* is the interchange format —
//! jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects.
//!
//! Python never runs here: the artifacts directory is the complete
//! contract between the build-time compile path and this runtime.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::Runtime;
pub use executor::Executor;
