//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) and
//! execute them from the coordinator's hot path.
//!
//! Flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  HLO *text* is the interchange format —
//! jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects.
//!
//! Python never runs here: the artifacts directory is the complete
//! contract between the build-time compile path and this runtime.
//!
//! The executable path ([`client`], [`executor`]) depends on the external
//! `xla` bindings and is gated behind the **`pjrt`** feature (off by
//! default — the offline build has neither the bindings nor compiled
//! artifacts).  The artifact manifest parser ([`artifact`]) is pure rust
//! and always available.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executor;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use executor::Executor;
