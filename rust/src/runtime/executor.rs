//! Typed artifact invocation: positional `xla::Literal` in/out with
//! shape validation against the manifest.
//!
//! Hot-path design: parameters and optimizer state stay as `Literal`s
//! between steps (the train artifacts return them and the next call
//! feeds them straight back) — host `Vec<f32>` conversion only happens
//! for scalars (loss, found_inf) and at init/readout.

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSpec, Dtype, TensorSpec};

/// A compiled artifact ready to run.
pub struct Executor {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executor {
    pub fn new(spec: ArtifactSpec, exe: xla::PjRtLoadedExecutable) -> Self {
        Executor { spec, exe }
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with positional literals; returns the flattened output
    /// tuple (aot.py lowers with return_tuple=True).
    ///
    /// Takes *borrowed* literals: `xla::PjRtLoadedExecutable::execute`
    /// accepts any `Borrow<Literal>`, so the hot path never deep-copies
    /// parameter tensors (§Perf L3: removed one full param-set memcpy
    /// per act/train invocation).
    pub fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching outputs of {}", self.spec.name))?;
        let outs = tuple.to_tuple().context("destructuring output tuple")?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

/// Build an f32 literal of `shape` from host data.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let elems: usize = shape.iter().product();
    if data.len() != elems {
        bail!("literal_f32: {} values for shape {:?}", data.len(), shape);
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)?)
}

/// Build an i32 literal of `shape` from host data.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let elems: usize = shape.iter().product();
    if data.len() != elems {
        bail!("literal_i32: {} values for shape {:?}", data.len(), shape);
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)?)
}

/// Scalar f32 literal.
pub fn scalar_f32(x: f32) -> Result<xla::Literal> {
    literal_f32(&[x], &[])
}

/// Read an f32 literal back to host.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read a scalar f32 output.
pub fn scalar_of(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Zero-filled literal for a tensor spec (optimizer-state init).
pub fn zeros(spec: &TensorSpec) -> Result<xla::Literal> {
    match spec.dtype {
        Dtype::F32 => literal_f32(&vec![0.0; spec.elems()], &spec.shape),
        Dtype::I32 => literal_i32(&vec![0; spec.elems()], &spec.shape),
    }
}
