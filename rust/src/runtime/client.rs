//! PJRT client wrapper: one CPU client per process, compiled-executable
//! cache keyed by artifact name.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::artifact::Manifest;
use super::executor::Executor;

/// The L3-side runtime: owns the PJRT client and the executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, Arc<Executor>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest from
    /// `dir` (usually `artifacts/`).
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<Arc<Executor>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&computation)
            .with_context(|| format!("compiling artifact {name}"))?;
        let executor = Arc::new(Executor::new(spec, exe));
        self.cache.insert(name.to_string(), executor.clone());
        Ok(executor)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}
