//! The disarmed-tracing overhead contract, asserted structurally: a
//! counting global allocator proves that the no-recorder span fast
//! path and the no-subscriber bus publish allocate **nothing** — the
//! instrumentation left compiled into every hot kernel costs one
//! relaxed atomic load and a branch.  (The wall-clock side of the same
//! contract is tracked by `bench_exec`'s `trace_disarmed_span/1k`
//! micro bench and its committed baseline.)
//!
//! This binary holds exactly one test so no concurrent test thread can
//! allocate inside the measured windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use apdrl::obs::trace::{self, Kernel};
use apdrl::obs::{self, Event};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn disarmed_span_and_no_subscriber_publish_allocate_nothing() {
    // Nothing arms tracing in this binary, and no subscriber attaches.
    assert!(!trace::active(), "this binary must never arm a recorder");
    assert!(!obs::active(), "this binary must never attach a subscriber");

    // Warm every lazy global (bus OnceLock, etc.) outside the windows.
    assert!(trace::span(Kernel::GemmNn, [8, 8, 8], 1).is_none());
    obs::publish(Event::new("warmup"));

    // Window 1: the disarmed span fast path.
    let before = allocs();
    for _ in 0..10_000 {
        let s = trace::span(Kernel::GemmNn, [64, 64, 64], 4);
        assert!(s.is_none());
    }
    assert_eq!(allocs() - before, 0, "disarmed span must not allocate");

    // Window 2: the trace::active() guard instrumented call sites use.
    let before = allocs();
    for _ in 0..10_000 {
        assert!(!trace::active());
    }
    assert_eq!(allocs() - before, 0, "the active() guard must not allocate");

    // Window 3: publishing pre-built events with no subscriber.  Event
    // construction allocates (strings) and happens before the window;
    // the publish itself must be a bare counter check.
    let events: Vec<Event> = (0..1_000)
        .map(|i| Event::new("trace.kernel").num("calls", i as f64))
        .collect();
    let before = allocs();
    for ev in events {
        obs::publish(ev);
    }
    assert_eq!(allocs() - before, 0, "no-subscriber publish must not allocate");
}
