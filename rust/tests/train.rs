//! The dynamic phase on the CPU execution backend — the tier-1 proof
//! that the paper's training half actually runs offline:
//!
//! * one real training loop per algorithm (DQN/A2C/PPO/DDPG) through
//!   `exec`, driven by the same `train_combo` entry the CLI uses;
//! * quantized runs provably route per-layer formats from the partition
//!   plan's `PrecisionPolicy` (asserted at the agent, model and weight
//!   level — not logged);
//! * a DQN-CartPole convergence smoke: mean reward improves over
//!   training, and the quantized run tracks the FP32 control within a
//!   stated tolerance;
//! * the training-as-a-service checkpoint contract: a job snapshotted
//!   every K env steps resumes **bit-identically** from any snapshot on
//!   a fresh backend — per algorithm, including the cancelled-job
//!   hand-off path the daemon federation rides.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use apdrl::coordinator::config::ComboConfig;
use apdrl::coordinator::metrics::RunMetrics;
use apdrl::coordinator::{
    combo, train_combo, train_combo_actors, train_combo_job, Checkpoint, JobOptions, LocalPlanner,
    PlanRequest, Planner, TrainLimits,
};
use apdrl::drl::compute::DqnCompute;
use apdrl::drl::replay::{ReplayBuffer, StoredAction};
use apdrl::drl::Agent;
use apdrl::envs::Env;
use apdrl::exec::{Backend, CpuBackend, CpuDqn, ExecPolicy, Pool};
use apdrl::graph::{Algo, NetSpec};
use apdrl::hw::Format;
use apdrl::quant::formats::round_to;
use apdrl::util::json::Json;
use apdrl::util::Rng;

/// A small custom combo so per-algorithm loop tests stay fast; envs and
/// algorithms are the real ones.
fn tiny_combo(
    name: &'static str,
    algo: Algo,
    env: &'static str,
    net: NetSpec,
    obs_dim: usize,
    act_dim: usize,
) -> ComboConfig {
    ComboConfig {
        name,
        algo,
        env,
        net,
        batch: 16,
        obs_dim,
        act_dim,
        paper_flops_per_row: 0.0,
        paper_reward_error_pct: 0.0,
    }
}

fn run(combo: &ComboConfig, backend: &mut CpuBackend, steps: u64) -> apdrl::coordinator::TrainResult {
    let limits = TrainLimits { max_env_steps: steps, max_episodes: 10_000 };
    train_combo(backend, combo, 1, limits, false).expect("training must run")
}

/// Acceptance: `cargo test` runs at least one *real* training loop per
/// algorithm through the exec backend — train steps taken, finite
/// losses, episodes collected.
#[test]
fn exec_backend_runs_dqn_training_loop() {
    let c = tiny_combo("dqn_t", Algo::Dqn, "cartpole", NetSpec::mlp(&[4, 24, 2]), 4, 2);
    let mut backend = CpuBackend::fp32().with_warmup(32).with_train_every(4);
    let r = run(&c, &mut backend, 600);
    assert!(r.metrics.train_steps > 50, "got {}", r.metrics.train_steps);
    assert!(!r.metrics.episode_rewards.is_empty());
    assert!(r.metrics.losses.iter().all(|l| l.is_finite()));
    assert_eq!(r.backend, "cpu exec (fp32)");
}

#[test]
fn exec_backend_runs_ddpg_training_loop() {
    let c = tiny_combo(
        "ddpg_t",
        Algo::Ddpg,
        "mntncarcont",
        NetSpec::mlp(&[2, 32, 32, 1]),
        2,
        1,
    );
    let mut backend = CpuBackend::fp32().with_warmup(64).with_train_every(4);
    let r = run(&c, &mut backend, 600);
    assert!(r.metrics.train_steps > 50, "got {}", r.metrics.train_steps);
    assert!(r.metrics.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn exec_backend_runs_a2c_training_loop() {
    // Registry combo (InvertedPendulum), shortened horizon.
    let c = combo("a2c_invpend");
    let mut backend = CpuBackend::fp32().with_batch(32);
    let r = run(&c, &mut backend, 700);
    assert!(r.metrics.train_steps >= 20, "got {}", r.metrics.train_steps);
    assert!(r.metrics.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn exec_backend_runs_ppo_training_loop_through_conv() {
    // Conv trunk on the synthetic pixel env: exercises the im2col path
    // end to end (12×12×4 frames).
    let c = tiny_combo(
        "ppo_t",
        Algo::Ppo,
        "mspacman_mini",
        NetSpec::Conv { in_hw: 12, in_ch: 4, conv: vec![(4, 4, 2)], fc: vec![32, 9] },
        12 * 12 * 4,
        9,
    );
    let mut backend = CpuBackend::fp32().with_batch(32);
    let r = run(&c, &mut backend, 700);
    // PPO runs `epochs` optimizer steps per rollout.
    assert!(r.metrics.train_steps >= 30, "got {}", r.metrics.train_steps);
    assert!(r.metrics.losses.iter().all(|l| l.is_finite()));
}

/// Acceptance: quantized runs *provably* route node formats per the
/// plan's `PrecisionPolicy` — asserted at three levels: the agent's
/// exposed policy, each model network's per-layer formats, and the
/// trained weights' bit patterns staying inside their storage format.
#[test]
fn quantized_training_routes_formats_from_the_plan() {
    let c = combo("dqn_cartpole");
    let plan = LocalPlanner
        .plan(&PlanRequest::new(c.clone(), c.batch, true))
        .expect("static phase");
    let expected = ExecPolicy::from_outcome(&plan).expect("policy from plan");
    assert!(expected.quantized && expected.needs_loss_scaling);

    // Level 1: the agent built by the backend executes exactly this policy.
    let mut backend = CpuBackend::from_outcome(&plan).expect("backend from plan");
    let agent = backend.make_agent(&c, 3).expect("agent");
    assert_eq!(agent.exec_policy(), Some(&expected), "agent routing != plan routing");

    // Level 2: every layer of every network carries the plan's formats.
    let mut model = CpuDqn::new(&c, &expected, 3);
    for (tag, net) in model.nets() {
        for (lname, fmt) in net.layer_formats() {
            assert_eq!(
                fmt,
                expected.layer(tag, &lname),
                "{tag}/{lname}: model format diverged from plan"
            );
        }
    }
    // The quantized CartPole plan is all-PL (Fig 15): FP16 compute with
    // FP32 masters on every weighted layer.
    for (tag, net) in model.nets() {
        for layer in &net.layers {
            assert_eq!(layer.fmt.fwd, Format::Fp16, "{tag}/{}", layer.name);
            if tag == "online" {
                assert!(layer.w.master.is_some(), "{tag}/{} missing master", layer.name);
            }
        }
    }

    // Level 3: after real train steps, working weights remain bit-exact
    // fixed points of their storage format (rounding actually applied),
    // while the FP32 masters have accumulated off-format values.
    let mut rng = Rng::new(5);
    let mut rb = ReplayBuffer::new(64, c.obs_dim);
    for _ in 0..64 {
        let o: Vec<f32> = (0..c.obs_dim).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        let o2: Vec<f32> = (0..c.obs_dim).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        rb.push(&o, StoredAction::Discrete(rng.below(2) as i32), 1.0, &o2, false);
    }
    for _ in 0..12 {
        let batch = rb.sample(32, &mut rng);
        model.train(&batch, 1024.0).expect("train step");
    }
    let mut moved = false;
    for (tag, net) in model.nets() {
        for layer in &net.layers {
            for (j, &w) in layer.w.value.data.iter().enumerate() {
                assert_eq!(
                    w.to_bits(),
                    round_to(w, layer.fmt.fwd).to_bits(),
                    "{tag}/{}: weight escaped its storage format",
                    layer.name
                );
                let m = layer.w.master.as_ref().expect("master armed")[j];
                assert_eq!(
                    w.to_bits(),
                    round_to(m, layer.fmt.fwd).to_bits(),
                    "{tag}/{}: working copy is not the rounded master",
                    layer.name
                );
                moved |= m != w;
            }
        }
    }
    assert!(moved, "masters must accumulate off-format values during training");
}

/// Acceptance: training is **bit-identical across thread counts**.
/// The mixed-precision DQN-CartPole run (live loss-scale FSM) with the
/// kernel pool at 1 vs 4 threads must produce identical per-episode
/// rewards (f64-exact) and an identical FSM transition log — the
/// blocked/parallel GEMM's per-element accumulation order never
/// depends on the thread count.
#[test]
fn dqn_training_is_bit_identical_across_thread_counts() {
    let c = combo("dqn_cartpole");
    let plan = LocalPlanner
        .plan(&PlanRequest::new(c.clone(), c.batch, true))
        .expect("static phase");
    let mut runs = Vec::new();
    for threads in [1usize, 4] {
        let mut backend = CpuBackend::from_outcome(&plan)
            .expect("backend")
            .with_train_every(2)
            .with_pool(Arc::new(Pool::new(threads)));
        let r = run(&c, &mut backend, 2_500);
        assert_eq!(r.threads, threads, "backend must report its pool size");
        assert!(r.metrics.train_steps > 100, "run too short to be meaningful");
        runs.push(r);
    }
    let (a, b) = (&runs[0].metrics, &runs[1].metrics);
    assert_eq!(
        a.episode_rewards, b.episode_rewards,
        "per-episode rewards diverged between 1 and 4 threads"
    );
    assert_eq!(
        a.scale_transitions, b.scale_transitions,
        "loss-scale FSM transition logs diverged between 1 and 4 threads"
    );
    assert_eq!(a.overflows, b.overflows);
    assert_eq!(a.final_loss_scale.to_bits(), b.final_loss_scale.to_bits());
    assert!(
        !a.scale_transitions.is_empty(),
        "the FSM must actually transition for this test to mean anything"
    );
}

/// Same contract through the conv/im2col path, whose large patch-row
/// GEMMs (batch·oh·ow rows) genuinely engage the parallel row-block
/// kernels at 4 threads.
#[test]
fn conv_training_is_bit_identical_across_thread_counts() {
    let c = tiny_combo(
        "ppo_thr",
        Algo::Ppo,
        "mspacman_mini",
        NetSpec::Conv { in_hw: 12, in_ch: 4, conv: vec![(4, 4, 2)], fc: vec![32, 9] },
        12 * 12 * 4,
        9,
    );
    let mut rewards = Vec::new();
    for threads in [1usize, 4] {
        let mut backend =
            CpuBackend::fp32().with_batch(32).with_pool(Arc::new(Pool::new(threads)));
        let r = run(&c, &mut backend, 600);
        assert!(r.metrics.train_steps >= 30, "got {}", r.metrics.train_steps);
        rewards.push((r.metrics.episode_rewards.clone(), r.metrics.losses.clone()));
    }
    assert_eq!(rewards[0].0, rewards[1].0, "conv episode rewards diverged across threads");
    assert_eq!(rewards[0].1, rewards[1].1, "conv per-step losses diverged across threads");
}

/// The historical scalar training loop, replicated verbatim from the
/// pre-batching trainer (one env, `rng.fork(0xE74)` env stream, stats
/// recorded at the pre-increment step count) — the reference the
/// `--actors 1` bit-identity guarantee is proved against.
fn scalar_reference_run(
    backend: &mut CpuBackend,
    c: &ComboConfig,
    seed: u64,
    limits: TrainLimits,
) -> RunMetrics {
    let mut agent = backend.make_agent(c, seed).expect("agent");
    let mut env = c.try_make_env().expect("env");
    let mut rng = Rng::new(seed);
    let mut env_rng = rng.fork(0xE74);
    let mut metrics = RunMetrics::default();
    let mut last_scale: Option<f32> = None;
    let mut obs = env.reset(&mut env_rng);
    let mut ep_reward = 0.0f64;
    let mut stats_buf = Vec::new();
    while metrics.env_steps < limits.max_env_steps
        && metrics.episode_rewards.len() < limits.max_episodes
    {
        let actions = agent.act(&obs, 1, &mut rng).expect("act");
        let tr = env.step(&actions[0], &mut env_rng);
        stats_buf.clear();
        agent
            .observe(
                &obs,
                &actions,
                &[tr.reward as f32],
                &tr.obs,
                &[tr.done],
                &mut rng,
                &mut stats_buf,
            )
            .expect("observe");
        for stats in &stats_buf {
            metrics.losses.push(stats.loss as f64);
            if stats.found_inf {
                metrics.overflows += 1;
            }
            if let Some(prev) = last_scale {
                if prev != stats.loss_scale {
                    metrics.scale_transitions.push((metrics.env_steps, prev, stats.loss_scale));
                }
            }
            last_scale = Some(stats.loss_scale);
            metrics.final_loss_scale = stats.loss_scale;
        }
        ep_reward += tr.reward;
        metrics.env_steps += 1;
        if tr.done {
            metrics.episode_rewards.push(ep_reward);
            ep_reward = 0.0;
            obs = env.reset(&mut env_rng);
        } else {
            obs = tr.obs;
        }
    }
    metrics.train_steps = agent.train_steps();
    metrics
}

/// Acceptance: `--actors 1` is **bit-identical** to the pre-refactor
/// scalar path.  Mixed-precision DQN-CartPole (live loss-scale FSM):
/// per-episode rewards, the full FSM transition log, per-step losses
/// and final scale must all match the scalar reference loop exactly.
#[test]
fn actors_1_is_bit_identical_to_the_scalar_path_dqn() {
    // A live observability subscriber on the global bus must not perturb
    // the run: events only observe (no RNG, no training state), so the
    // bit-identity below holds with the bus hot.
    let _watch = apdrl::obs::global().subscribe();
    let c = combo("dqn_cartpole");
    let plan = LocalPlanner
        .plan(&PlanRequest::new(c.clone(), c.batch, true))
        .expect("static phase");
    let limits = TrainLimits { max_env_steps: 2_500, max_episodes: 10_000 };
    let mut ref_backend = CpuBackend::from_outcome(&plan).expect("backend").with_train_every(2);
    let reference = scalar_reference_run(&mut ref_backend, &c, 1, limits);
    let mut backend = CpuBackend::from_outcome(&plan).expect("backend").with_train_every(2);
    let r = train_combo_actors(&mut backend, &c, 1, limits, 1, false).expect("train");
    assert_eq!(r.actors, 1);
    assert!(
        !reference.scale_transitions.is_empty(),
        "the FSM must actually transition for this test to mean anything"
    );
    let m = &r.metrics;
    assert_eq!(reference.episode_rewards, m.episode_rewards, "episode rewards diverged");
    assert_eq!(reference.scale_transitions, m.scale_transitions, "FSM logs diverged");
    assert_eq!(reference.losses, m.losses, "per-step losses diverged");
    assert_eq!(reference.overflows, m.overflows);
    assert_eq!(reference.final_loss_scale.to_bits(), m.final_loss_scale.to_bits());
    assert_eq!(reference.train_steps, m.train_steps);
    assert_eq!(reference.env_steps, m.env_steps);
}

/// Same bit-identity contract through the conv/im2col path (on-policy
/// PPO: rollout buffer, GAE and bootstrap instead of replay sampling).
#[test]
fn actors_1_is_bit_identical_to_the_scalar_path_conv_ppo() {
    let c = tiny_combo(
        "ppo_bit",
        Algo::Ppo,
        "mspacman_mini",
        NetSpec::Conv { in_hw: 12, in_ch: 4, conv: vec![(4, 4, 2)], fc: vec![32, 9] },
        12 * 12 * 4,
        9,
    );
    let limits = TrainLimits { max_env_steps: 600, max_episodes: 10_000 };
    let mut ref_backend = CpuBackend::fp32().with_batch(32);
    let reference = scalar_reference_run(&mut ref_backend, &c, 1, limits);
    let mut backend = CpuBackend::fp32().with_batch(32);
    let r = train_combo_actors(&mut backend, &c, 1, limits, 1, false).expect("train");
    assert!(reference.train_steps >= 30, "run too short to be meaningful");
    assert_eq!(reference.episode_rewards, r.metrics.episode_rewards);
    assert_eq!(reference.losses, r.metrics.losses);
    assert_eq!(reference.train_steps, r.metrics.train_steps);
    assert_eq!(reference.env_steps, r.metrics.env_steps);
}

/// Acceptance: an 8-lane fleet still *learns* — DQN-CartPole reward
/// improves over training and reaches a sane converged level.  (The
/// per-lane RNG streams differ from the scalar run's, so thresholds are
/// generous; exact equivalence at N=1 is proved separately above.)
#[test]
fn actors_8_dqn_cartpole_converges() {
    let c = combo("dqn_cartpole");
    let plan = LocalPlanner
        .plan(&PlanRequest::new(c.clone(), c.batch, true))
        .expect("static phase");
    let mut backend = CpuBackend::from_outcome(&plan).expect("backend").with_train_every(2);
    let limits = TrainLimits { max_env_steps: 6_000, max_episodes: 10_000 };
    let r = train_combo_actors(&mut backend, &c, 1, limits, 8, false).expect("train");
    assert_eq!(r.actors, 8);
    let n = r.metrics.episode_rewards.len();
    assert!(n >= 40, "too few episodes: {n}");
    let quarter = (n / 4).max(1);
    let early: f64 = r.metrics.episode_rewards[..quarter].iter().sum::<f64>() / quarter as f64;
    let late: f64 = r.metrics.episode_rewards[n - quarter..].iter().sum::<f64>() / quarter as f64;
    assert!(
        late >= 1.3 * early,
        "8-actor reward must improve over training (early {early:.1}, late {late:.1})"
    );
    let last25 = r.metrics.converged_reward(25);
    assert!(last25 >= 30.0, "8-actor converged reward too low: {last25:.1}");
    assert!(r.metrics.train_steps > 100, "fleet run took too few train steps");
}

/// Acceptance: batching actually buys collection throughput.  Measured
/// on a collection-only config (warmup larger than the budget, so no
/// train steps run and the comparison isolates act + env stepping):
/// 8 lanes must collect more env-steps/sec than 1.
#[test]
fn actors_8_out_collects_the_scalar_path() {
    let c = combo("dqn_cartpole");
    let limits = TrainLimits { max_env_steps: 5_000, max_episodes: 100_000 };
    let mut rates = Vec::new();
    for actors in [1usize, 8] {
        let mut backend = CpuBackend::fp32().with_warmup(1_000_000);
        let r = train_combo_actors(&mut backend, &c, 7, limits, actors, false).expect("train");
        assert_eq!(r.metrics.train_steps, 0, "warmup must suppress training here");
        assert!(r.metrics.env_steps >= limits.max_env_steps);
        rates.push(r.metrics.env_steps_per_sec());
    }
    assert!(
        rates[1] > rates[0],
        "8 actors must out-collect 1 ({:.0} vs {:.0} env-steps/s)",
        rates[1],
        rates[0]
    );
}

/// Run one `train_combo_job` with job hooks attached (seed 1, one
/// actor, quiet), collecting every streamed frame.
fn run_job(
    backend: &mut CpuBackend,
    c: &ComboConfig,
    limits: TrainLimits,
    checkpoint_every: u64,
    quantized: bool,
    cancel: Option<&AtomicBool>,
    resume: Option<&Checkpoint>,
) -> (apdrl::coordinator::TrainResult, Vec<Json>) {
    let mut frames: Vec<Json> = Vec::new();
    let mut sink = |f: &Json| frames.push(f.clone());
    let opts = JobOptions {
        job_id: Some("ckpt-test".into()),
        cancel,
        checkpoint_every,
        progress_every: 0,
        sink: Some(&mut sink),
        resume,
        quantized,
    };
    let r = train_combo_job(backend, c, 1, limits, 1, false, opts).expect("training must run");
    (r, frames)
}

/// Every checkpoint carried by the streamed frames, in emission order
/// (periodic snapshots first, the final one last).
fn checkpoints_of(frames: &[Json]) -> Vec<Checkpoint> {
    frames
        .iter()
        .filter(|f| f.get("frame").and_then(Json::as_str) == Some("checkpoint"))
        .map(|f| {
            Checkpoint::from_json(f.get("data").expect("checkpoint data"))
                .expect("checkpoint must parse")
        })
        .collect()
}

/// Everything a training trajectory is, compared bit-for-bit (wall
/// clock excepted — it is the one field allowed to differ).
fn assert_metrics_bit_identical(a: &RunMetrics, b: &RunMetrics) {
    assert_eq!(a.episode_rewards, b.episode_rewards, "episode rewards diverged");
    assert_eq!(a.losses, b.losses, "per-step losses diverged");
    assert_eq!(a.scale_transitions, b.scale_transitions, "loss-scale FSM logs diverged");
    assert_eq!(a.overflows, b.overflows, "overflow counts diverged");
    assert_eq!(a.final_loss_scale.to_bits(), b.final_loss_scale.to_bits());
    assert_eq!(a.train_steps, b.train_steps, "train step counts diverged");
    assert_eq!(a.env_steps, b.env_steps, "env step counts diverged");
}

/// The checkpoint-resume contract for one combo/backend: an
/// uninterrupted reference run vs. the same job resumed on a *fresh*
/// backend from its first mid-run snapshot.  Rewards, losses, FSM log
/// and final state (agent weights + Adam moments + loss-scale FSM, env
/// fleet, master RNG) must match bit-for-bit — checkpoint payloads
/// encode floats as raw bits, so `Json` equality *is* bit equality.
/// Returns the reference metrics for combo-specific extra assertions.
fn assert_resume_is_bit_identical(
    c: &ComboConfig,
    make_backend: &dyn Fn() -> CpuBackend,
    steps: u64,
    every: u64,
    quantized: bool,
) -> RunMetrics {
    let limits = TrainLimits { max_env_steps: steps, max_episodes: 10_000 };
    let (reference, ref_frames) =
        run_job(&mut make_backend(), c, limits, every, quantized, None, None);
    assert!(!reference.cancelled);
    let ref_ckpts = checkpoints_of(&ref_frames);
    assert!(ref_ckpts.len() >= 2, "need a mid-run checkpoint and a final one");
    let mid = &ref_ckpts[0];
    assert!(
        mid.metrics.env_steps > 0 && mid.metrics.env_steps < reference.metrics.env_steps,
        "first checkpoint must be mid-run ({} of {})",
        mid.metrics.env_steps,
        reference.metrics.env_steps
    );
    let (resumed, res_frames) =
        run_job(&mut make_backend(), c, limits, every, quantized, None, Some(mid));
    assert!(!resumed.cancelled);
    assert_metrics_bit_identical(&reference.metrics, &resumed.metrics);
    let ref_final = ref_ckpts.last().expect("final checkpoint");
    let res_final = checkpoints_of(&res_frames).pop().expect("final checkpoint");
    assert_eq!(
        ref_final.agent, res_final.agent,
        "final agent state (weights, moments, FSM) diverged after resume"
    );
    assert_eq!(ref_final.fleet, res_final.fleet, "env fleet state diverged after resume");
    assert_eq!(ref_final.rng_state, res_final.rng_state, "master RNG diverged after resume");
    assert_eq!(
        ref_final.rng_spare.map(f64::to_bits),
        res_final.rng_spare.map(f64::to_bits),
        "master RNG spare diverged after resume"
    );
    reference.metrics
}

/// Acceptance (training-as-a-service): quantized DQN — replay buffer,
/// FP32 masters and the *live* loss-scale FSM must all survive the
/// checkpoint round trip.
#[test]
fn checkpoint_resume_is_bit_identical_quantized_dqn() {
    let c = combo("dqn_cartpole");
    let plan = LocalPlanner
        .plan(&PlanRequest::new(c.clone(), c.batch, true))
        .expect("static phase");
    let make = || CpuBackend::from_outcome(&plan).expect("backend").with_train_every(2);
    let m = assert_resume_is_bit_identical(&c, &make, 2_500, 500, true);
    assert!(
        !m.scale_transitions.is_empty(),
        "the FSM must actually transition for this test to mean anything"
    );
}

/// Conv PPO (im2col trunk, on-policy rollout buffer + GAE state).
#[test]
fn checkpoint_resume_is_bit_identical_conv_ppo() {
    let c = tiny_combo(
        "ppo_ckpt",
        Algo::Ppo,
        "mspacman_mini",
        NetSpec::Conv { in_hw: 12, in_ch: 4, conv: vec![(4, 4, 2)], fc: vec![32, 9] },
        12 * 12 * 4,
        9,
    );
    let make = || CpuBackend::fp32().with_batch(32);
    let m = assert_resume_is_bit_identical(&c, &make, 600, 150, false);
    assert!(m.train_steps >= 30, "run too short to be meaningful: {}", m.train_steps);
}

/// A2C (on-policy, registry InvertedPendulum combo).
#[test]
fn checkpoint_resume_is_bit_identical_a2c() {
    let c = combo("a2c_invpend");
    let make = || CpuBackend::fp32().with_batch(32);
    let m = assert_resume_is_bit_identical(&c, &make, 700, 200, false);
    assert!(m.train_steps >= 20, "run too short to be meaningful: {}", m.train_steps);
}

/// DDPG (off-policy continuous control: actor/critic/targets + replay).
#[test]
fn checkpoint_resume_is_bit_identical_ddpg() {
    let c = tiny_combo(
        "ddpg_ckpt",
        Algo::Ddpg,
        "mntncarcont",
        NetSpec::mlp(&[2, 32, 32, 1]),
        2,
        1,
    );
    let make = || CpuBackend::fp32().with_warmup(64).with_train_every(4);
    let m = assert_resume_is_bit_identical(&c, &make, 600, 150, false);
    assert!(m.train_steps >= 50, "run too short to be meaningful: {}", m.train_steps);
}

/// The hand-off path end to end, in-process: a job cancelled mid-run
/// emits a final checkpoint (what a draining daemon streams to its
/// client), and a fresh backend resuming from it finishes with metrics
/// and weights bit-identical to the never-interrupted reference.
#[test]
fn cancelled_dqn_job_hands_off_and_resumes_bit_identically() {
    let c = combo("dqn_cartpole");
    let plan = LocalPlanner
        .plan(&PlanRequest::new(c.clone(), c.batch, true))
        .expect("static phase");
    let limits = TrainLimits { max_env_steps: 2_500, max_episodes: 10_000 };
    let mut backend = CpuBackend::from_outcome(&plan).expect("backend").with_train_every(2);
    let (reference, ref_frames) = run_job(&mut backend, &c, limits, 500, true, None, None);
    let ref_final = checkpoints_of(&ref_frames).pop().expect("final checkpoint");

    // Cancelled half: flip the cooperative flag from the sink once the
    // stream passes 1 000 env steps — a round boundary later, the loop
    // stops and emits its hand-off checkpoint.
    let cancel = AtomicBool::new(false);
    let mut frames: Vec<Json> = Vec::new();
    let mut sink = |f: &Json| {
        if f.get("env_steps").and_then(Json::as_f64).unwrap_or(0.0) >= 1_000.0 {
            cancel.store(true, Ordering::SeqCst);
        }
        frames.push(f.clone());
    };
    let mut backend = CpuBackend::from_outcome(&plan).expect("backend").with_train_every(2);
    let opts = JobOptions {
        job_id: Some("handoff".into()),
        cancel: Some(&cancel),
        checkpoint_every: 500,
        progress_every: 0,
        sink: Some(&mut sink),
        resume: None,
        quantized: true,
    };
    let half = train_combo_job(&mut backend, &c, 1, limits, 1, false, opts).expect("train");
    assert!(half.cancelled, "the cancel flag must stop the run");
    assert!(half.metrics.env_steps < reference.metrics.env_steps, "cancel must stop mid-run");
    let handoff = checkpoints_of(&frames).pop().expect("hand-off checkpoint");

    // Survivor half: resume from the hand-off snapshot to completion.
    let mut backend = CpuBackend::from_outcome(&plan).expect("backend").with_train_every(2);
    let (resumed, res_frames) = run_job(&mut backend, &c, limits, 500, true, None, Some(&handoff));
    assert!(!resumed.cancelled);
    assert_metrics_bit_identical(&reference.metrics, &resumed.metrics);
    let res_final = checkpoints_of(&res_frames).pop().expect("final checkpoint");
    assert_eq!(res_final.agent, ref_final.agent, "weights diverged across the hand-off");
}

/// The FP32 control routes everything FP32 with no scaler and no masters.
#[test]
fn fp32_control_backend_routes_fp32() {
    let c = combo("dqn_cartpole");
    let plan = LocalPlanner
        .plan(&PlanRequest::new(c.clone(), c.batch, false))
        .expect("static phase");
    let policy = ExecPolicy::from_outcome(&plan).expect("policy");
    assert!(!policy.quantized && !policy.needs_loss_scaling);
    let model = CpuDqn::new(&c, &policy, 1);
    for (_, net) in model.nets() {
        for layer in &net.layers {
            assert_eq!(layer.fmt.fwd, Format::Fp32);
            assert!(layer.w.master.is_none());
        }
    }
}

/// Acceptance: the convergence smoke.  DQN-CartPole mean reward must
/// improve over training on the exec backend, and the quantized run
/// (FP16 + masters + live loss-scaling FSM, per the plan) must track
/// the FP32 control within a stated tolerance of 40% relative converged
/// reward (the paper's Table III reports 1.6%; the tolerance here is
/// loose because the budget is a 5k-step smoke, not a full run).
#[test]
fn dqn_cartpole_converges_and_quantized_tracks_fp32() {
    let c = combo("dqn_cartpole");
    let mut converged = Vec::new();
    for quantized in [false, true] {
        let plan = LocalPlanner
            .plan(&PlanRequest::new(c.clone(), c.batch, quantized))
            .expect("static phase");
        let mut backend =
            CpuBackend::from_outcome(&plan).expect("backend").with_train_every(2);
        let r = run(&c, &mut backend, 5_000);
        let n = r.metrics.episode_rewards.len();
        assert!(n >= 40, "too few episodes: {n}");
        let quarter = (n / 4).max(1);
        let early: f64 =
            r.metrics.episode_rewards[..quarter].iter().sum::<f64>() / quarter as f64;
        let late: f64 =
            r.metrics.episode_rewards[n - quarter..].iter().sum::<f64>() / quarter as f64;
        assert!(
            late >= 2.0 * early,
            "{}: reward must improve over training (early {early:.1}, late {late:.1})",
            r.backend
        );
        let last25 = r.metrics.converged_reward(25);
        assert!(last25 >= 45.0, "{}: converged reward too low: {last25:.1}", r.backend);
        if quantized {
            // The FSM must be *live*: FP16 gradients overflow at the
            // initial 65536 scale and the scale backs off.
            assert!(r.metrics.overflows >= 1, "loss-scaling FSM saw no overflow");
            assert!(
                r.metrics.scale_transitions.iter().any(|(_, from, to)| to < from),
                "loss-scaling FSM never backed off: {:?}",
                r.metrics.scale_transitions
            );
            assert!(r.metrics.final_loss_scale > 0.0, "no train step recorded a scale");
        } else {
            assert_eq!(r.metrics.overflows, 0, "fp32 must not overflow");
        }
        converged.push(last25);
    }
    let (fp32, quant) = (converged[0], converged[1]);
    let rel = (quant - fp32).abs() / fp32;
    assert!(
        rel <= 0.40,
        "quantized ({quant:.1}) must track fp32 ({fp32:.1}) within 40% (got {:.0}%)",
        rel * 100.0
    );
}
