//! Integration tests for the static-phase planning service: memoized
//! `static_phase`, the batched `plan_sweep` API, JSON persistence of the
//! plan cache, and parallel/sequential solver agreement.  These run on
//! the default (non-`pjrt`) feature set — no artifacts needed.

use apdrl::coordinator::{combo, plan_sweep, plan_sweep_grid, static_phase, PlanRequest};
use apdrl::graph::build_train_graph;
use apdrl::hw::vek280;
use apdrl::partition::cache::{PlanCache, PlanKey};
use apdrl::partition::{solve_ilp_capped, solve_ilp_sequential, Problem};
use apdrl::profile::profile_dag;

/// The acceptance-criteria scenario: a repeated static_phase call for the
/// same (combo, batch, quantized) key must hit the plan cache — zero
/// explored nodes, cache-hit flag set, identical schedule.
#[test]
fn second_solve_is_a_cache_hit_with_identical_schedule() {
    let c = combo("a2c_invpend");
    let fresh = static_phase(&c, 112, true);
    assert!(fresh.solution.explored > 0, "first solve must actually search");
    let cached = static_phase(&c, 112, true);
    assert!(cached.cache_hit);
    assert_eq!(cached.solution.explored, 0);
    assert_eq!(cached.solution.assignment, fresh.solution.assignment);
    assert_eq!(
        cached.solution.makespan_us.to_bits(),
        fresh.solution.makespan_us.to_bits()
    );
    for (a, b) in cached.schedule.entries.iter().zip(&fresh.schedule.entries) {
        assert_eq!((a.node, a.component), (b.node, b.component));
        assert_eq!(a.start_us.to_bits(), b.start_us.to_bits());
        assert_eq!(a.finish_us.to_bits(), b.finish_us.to_bits());
    }
}

/// Different keys must not alias: fp32 vs quantized and different batch
/// sizes get their own plans.
#[test]
fn cache_never_aliases_across_keys() {
    let c = combo("dqn_cartpole");
    let quant = static_phase(&c, 72, true);
    let fp32 = static_phase(&c, 72, false);
    assert!(!fp32.cache_hit, "fp32 must not reuse the quantized plan");
    // Quantized and fp32 pipelines profile different formats; at minimum
    // the precision policies must differ.
    assert_ne!(
        quant.policy.node_format, fp32.policy.node_format,
        "precision policies must reflect the mode"
    );
    let other_bs = static_phase(&c, 73, true);
    assert!(!other_bs.cache_hit, "a new batch size is a new plan");
}

/// plan_sweep over a mixed grid returns plans in request order and
/// agrees with individual solves.
#[test]
fn sweep_results_are_order_stable_and_correct() {
    let reqs = vec![
        PlanRequest::new(combo("dqn_cartpole"), 40, true),
        PlanRequest::new(combo("a2c_invpend"), 40, false),
        PlanRequest::new(combo("ddpg_mntncar"), 40, true),
    ];
    let plans = plan_sweep(&reqs);
    assert_eq!(plans.len(), reqs.len());
    for (req, plan) in reqs.iter().zip(&plans) {
        assert_eq!(plan.dag.len(), build_train_graph(&req.combo.train_spec(req.batch)).len());
        let solo = static_phase(&req.combo, req.batch, req.quantized);
        assert_eq!(plan.solution.assignment, solo.solution.assignment);
        assert_eq!(
            plan.solution.makespan_us.to_bits(),
            solo.solution.makespan_us.to_bits()
        );
    }
}

/// The grid helper covers the full cross product in combo-major order.
#[test]
fn grid_sweep_covers_cross_product() {
    let combos = [combo("dqn_cartpole"), combo("a2c_invpend")];
    let batches = [24usize, 56];
    let plans = plan_sweep_grid(&combos, &batches, true);
    assert_eq!(plans.len(), 4);
    for (i, plan) in plans.iter().enumerate() {
        let expect = build_train_graph(
            &combos[i / batches.len()].train_spec(batches[i % batches.len()]),
        );
        assert_eq!(plan.dag.len(), expect.len());
    }
}

/// An explicitly file-backed cache round-trips plans across instances
/// (what `APDRL_PLAN_CACHE` gives the global cache).
#[test]
fn file_backed_cache_survives_reload() {
    let c = combo("ddpg_mntncar");
    let platform = vek280();
    let spec = c.train_spec(44);
    let dag = build_train_graph(&spec);
    let profiles = profile_dag(&dag, &platform, true);
    let problem = Problem::new(&dag, &profiles, &platform, true);
    let solution = solve_ilp_capped(&problem, 300_000);
    let key = PlanKey::new(&spec, true, &platform);

    let path = std::env::temp_dir().join("apdrl_planner_it").join("cache.json");
    let _ = std::fs::remove_file(&path);
    {
        let mut cache = PlanCache::with_persistence(&path);
        cache.insert(&key, &solution);
        cache.save();
    }
    let mut reloaded = PlanCache::with_persistence(&path);
    let hit = reloaded.lookup(&key, &profiles).expect("plan must survive reload");
    assert_eq!(hit.assignment, solution.assignment);
    assert_eq!(hit.makespan_us.to_bits(), solution.makespan_us.to_bits());
    assert_eq!(hit.explored, 0);
    let _ = std::fs::remove_file(&path);
}

/// Parallel prefix fan-out and the sequential DFS are both exact: same
/// optimal makespan on a real workload.
#[test]
fn parallel_and_sequential_solvers_agree_end_to_end() {
    let c = combo("ddpg_lunar");
    let platform = vek280();
    let dag = build_train_graph(&c.train_spec(256));
    let profiles = profile_dag(&dag, &platform, true);
    let problem = Problem::new(&dag, &profiles, &platform, true);
    // Headroom so neither search hits the cap (abort would void the
    // exactness argument the equality rests on).
    let par = solve_ilp_capped(&problem, 2_000_000);
    let seq = solve_ilp_sequential(&problem, 2_000_000);
    assert!(
        (par.makespan_us - seq.makespan_us).abs() < 1e-9,
        "parallel {} vs sequential {}",
        par.makespan_us,
        seq.makespan_us
    );
}
