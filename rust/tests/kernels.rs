//! Kernel-equivalence suite: the cache-blocked and pool-parallel GEMM
//! kernels must be **bit-identical** (`==` on `data`) to the naive
//! triple-loop references for all three variants, at every thread
//! count.  This is the contract that lets plan-driven mixed-precision
//! training change thread counts without perturbing the loss-scale FSM
//! or reward trajectories.
//!
//! The sweep crosses every blocking boundary of the implementation
//! (MR=4 / NR=8 micro-tiles, MC=32 row blocks, KC=256 reduction
//! blocks): degenerate dims {0, 1}, sub-tile {7}, exactly-one-block
//! {64}, off-by-one-past-blocks {129}, plus rectangular extremes.

use std::sync::Arc;

use apdrl::exec::{Pool, Tensor};
use apdrl::util::Rng;

/// Values with a wide dynamic range so any reordered f32 summation
/// would actually produce different bits (uniform [-1,1] sums can
/// mask reassociation).
fn rand_tensor(rng: &mut Rng, rows: usize, cols: usize) -> Tensor {
    let data = (0..rows * cols)
        .map(|_| {
            let mag = 2.0f64.powi((rng.below(17) as i32) - 8);
            (rng.normal() * mag) as f32
        })
        .collect();
    Tensor::from_vec(data, &[rows, cols])
}

fn assert_bits_eq(got: &Tensor, want: &Tensor, what: &str) {
    assert_eq!(got.shape, want.shape, "{what}: shape");
    assert_eq!(got.data.len(), want.data.len(), "{what}: len");
    for (i, (g, w)) in got.data.iter().zip(want.data.iter()).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: elem {i} diverged ({g} vs {w})"
        );
    }
}

/// The shape sweep: the full cross product over the boundary dims plus
/// rectangular extremes (long-thin, thin-long, KC-crossing).
fn sweep_shapes() -> Vec<(usize, usize, usize)> {
    const DIMS: [usize; 5] = [0, 1, 7, 64, 129];
    let mut shapes = Vec::new();
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                shapes.push((m, k, n));
            }
        }
    }
    shapes.extend([
        (1, 513, 1),    // dot product crossing the KC=256 boundary twice
        (257, 3, 2),    // many row blocks, tiny panel
        (2, 300, 33),   // KC boundary + strip tail
        (33, 65, 257),  // every dimension one past a block boundary
        (5, 1024, 5),   // reduction-dominant
    ]);
    shapes
}

#[test]
fn blocked_and_parallel_gemm_bit_identical_to_naive() {
    let pools: Vec<Arc<Pool>> =
        [1usize, 2, 8].iter().map(|&t| Arc::new(Pool::new(t))).collect();
    let mut rng = Rng::new(0x6E44);
    let shapes = sweep_shapes();
    assert!(shapes.len() >= 40, "sweep must cover at least ~40 shape triples");
    for (m, k, n) in shapes {
        // Operands per variant: matmul a(m,k)·b(k,n); tn a(m,k)ᵀ·g(m,n);
        // nt a(m,k)·bt(n,k)ᵀ.
        let a = rand_tensor(&mut rng, m, k);
        let b = rand_tensor(&mut rng, k, n);
        let g = rand_tensor(&mut rng, m, n);
        let bt = rand_tensor(&mut rng, n, k);
        let want_mm = a.matmul_naive(&b);
        let want_tn = a.matmul_tn_naive(&g);
        let want_nt = a.matmul_nt_naive(&bt);
        for pool in &pools {
            let tag = format!("({m},{k},{n}) @ {} threads", pool.threads());
            assert_bits_eq(&a.matmul_with(&b, pool), &want_mm, &format!("matmul {tag}"));
            assert_bits_eq(&a.matmul_tn_with(&g, pool), &want_tn, &format!("matmul_tn {tag}"));
            assert_bits_eq(&a.matmul_nt_with(&bt, pool), &want_nt, &format!("matmul_nt {tag}"));
        }
    }
}

/// The default entry points (process-wide `APDRL_THREADS` pool) obey
/// the same contract — whatever that pool's size happens to be.
#[test]
fn default_entry_points_match_naive() {
    let mut rng = Rng::new(0xDEF);
    let a = rand_tensor(&mut rng, 70, 45);
    let b = rand_tensor(&mut rng, 45, 33);
    let g = rand_tensor(&mut rng, 70, 33);
    let bt = rand_tensor(&mut rng, 33, 45);
    assert_bits_eq(&a.matmul(&b), &a.matmul_naive(&b), "matmul/global");
    assert_bits_eq(&a.matmul_tn(&g), &a.matmul_tn_naive(&g), "matmul_tn/global");
    assert_bits_eq(&a.matmul_nt(&bt), &a.matmul_nt_naive(&bt), "matmul_nt/global");
}

/// Repeated invocations on one pool (the training-loop pattern: many
/// GEMMs reusing the same workers) stay bit-stable call after call.
#[test]
fn repeated_runs_on_one_pool_are_stable() {
    let pool = Arc::new(Pool::new(4));
    let mut rng = Rng::new(0x5AB);
    let a = rand_tensor(&mut rng, 129, 80);
    let b = rand_tensor(&mut rng, 80, 65);
    let want = a.matmul_naive(&b);
    for round in 0..20 {
        let got = a.matmul_with(&b, &pool);
        assert_bits_eq(&got, &want, &format!("round {round}"));
    }
}

/// Non-finite inputs (overflowed FP16 gradients carry ±inf into the
/// GEMMs that follow) propagate identically: every finite and ±inf
/// element matches the naive reference bit-for-bit, and NaNs appear at
/// exactly the same positions.  (NaN *payloads* are the one thing left
/// unpinned: IEEE lets `fadd` operand commutation pick either quiet
/// payload, and the `found_inf` probe only asks `is_finite`.)
#[test]
fn non_finite_propagation_matches_naive() {
    let mut rng = Rng::new(0x1F);
    for threads in [1usize, 8] {
        let pool = Arc::new(Pool::new(threads));
        let mut a = rand_tensor(&mut rng, 40, 37);
        a.data[5] = f32::INFINITY;
        a.data[41] = f32::NEG_INFINITY;
        a.data[80] = f32::NAN;
        let b = rand_tensor(&mut rng, 37, 19);
        let want = a.matmul_naive(&b);
        let got = a.matmul_with(&b, &pool);
        assert!(want.has_non_finite() && got.has_non_finite());
        for (i, (g, w)) in got.data.iter().zip(want.data.iter()).enumerate() {
            if w.is_nan() {
                assert!(g.is_nan(), "elem {i} @ {threads} threads: NaN position lost");
            } else {
                assert_eq!(g.to_bits(), w.to_bits(), "elem {i} @ {threads} threads");
            }
        }
    }
}

/// Zero-sized-dim regression (found while hardening `Tensor::cols`):
/// empty operands must flow through all variants with conformable
/// shapes and exact-zero outputs, identically to the naive loops.
#[test]
fn zero_dim_shapes_are_conformable_and_exact() {
    let pool = Arc::new(Pool::new(2));
    // Empty batch through a dense-layer-shaped pipeline: fwd, dw, dx.
    let x = Tensor::zeros(&[0, 8]); // (batch=0, din)
    let w = Tensor::zeros(&[8, 4]);
    let y = x.matmul_with(&w, &pool);
    assert_eq!(y.shape, vec![0, 4]);
    let dz = Tensor::zeros(&[0, 4]);
    let dw = x.matmul_tn_with(&dz, &pool);
    assert_eq!(dw.shape, vec![8, 4]);
    assert_eq!(dw.data, vec![0.0; 32], "dw over an empty batch is exactly zero");
    assert_eq!(dw.data, x.matmul_tn_naive(&dz).data);
    let dx = dz.matmul_nt_with(&w, &pool);
    assert_eq!(dx.shape, vec![0, 8]);
    // Zero-width features (k = 0).
    let a = Tensor::zeros(&[6, 0]);
    let b = Tensor::zeros(&[0, 9]);
    let c = a.matmul_with(&b, &pool);
    assert_eq!(c.shape, vec![6, 9]);
    assert_eq!(c.data, vec![0.0; 54]);
    assert_eq!(c.data, a.matmul_naive(&b).data);
}
