//! Integration tests for the unified `Planner` API and the federated
//! backend: the same grid planned through `LocalPlanner`, a single
//! `RemotePlanner` and a two-daemon `FederatedPlanner` must be
//! *bit-identical* — including when one federated host is down and the
//! fail-over path serves its shards.  Everything runs on the default
//! (non-`pjrt`) feature set over loopback TCP.

use apdrl::coordinator::{LocalPlanner, PlanOutcome, PlanRequest, Planner, Provenance};
use apdrl::server::{FederatedPlanner, RemotePlanner, Server};

/// Boot a daemon on an ephemeral loopback port; returns its address and
/// the thread running it (joined after `shutdown`).
fn boot(workers: usize) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", workers).expect("ephemeral bind must work");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run().expect("server run must not error"));
    (addr, handle)
}

/// The acceptance grid: two combos × two batches plus one fp32 point —
/// enough to land on both shards of a two-host federation in practice
/// while staying fast.
fn grid() -> Vec<PlanRequest> {
    let mut reqs =
        PlanRequest::named_grid(&["dqn_cartpole".into(), "a2c_invpend".into()], &[28, 60], true)
            .unwrap();
    reqs.push(PlanRequest::named("ddpg_mntncar").unwrap().with_batch(28).fp32());
    reqs
}

/// Everything except provenance must agree bit-for-bit across backends.
fn assert_identical(tag: &str, a: &[PlanOutcome], b: &[PlanOutcome]) {
    assert_eq!(a.len(), b.len(), "{tag}: plan counts differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.combo, y.combo, "{tag}");
        assert_eq!(x.batch, y.batch, "{tag}");
        assert_eq!(x.quantized, y.quantized, "{tag}");
        assert_eq!(
            x.makespan_us.to_bits(),
            y.makespan_us.to_bits(),
            "{tag}: {} bs={} makespans differ",
            x.combo,
            x.batch
        );
        assert_eq!(x.assignment, y.assignment, "{tag}: {} bs={}", x.combo, x.batch);
        assert_eq!(x.schedule.len(), y.schedule.len(), "{tag}");
        for (s, t) in x.schedule.iter().zip(&y.schedule) {
            assert_eq!(
                (s.node, &s.component, &s.format, s.mm),
                (t.node, &t.component, &t.format, t.mm),
                "{tag}"
            );
            assert_eq!(s.start_us.to_bits(), t.start_us.to_bits(), "{tag}");
            assert_eq!(s.finish_us.to_bits(), t.finish_us.to_bits(), "{tag}");
        }
        assert_eq!(x.step_time_us().to_bits(), y.step_time_us().to_bits(), "{tag}");
    }
}

/// The tentpole acceptance scenario: local, remote and federated
/// backends plan the same grid identically; killing one federated host
/// exercises the retry path and the results are *still* identical.
#[test]
fn all_three_backends_plan_identically_even_with_a_host_down() {
    let (addr_a, handle_a) = boot(2);
    let (addr_b, handle_b) = boot(2);
    let reqs = grid();

    let local = LocalPlanner.plan_many(&reqs).unwrap();
    assert!(local
        .iter()
        .all(|p| matches!(p.provenance, Provenance::Local { .. })));

    let remote_backend = RemotePlanner::connect(&addr_a).unwrap();
    let remote = remote_backend.plan_many(&reqs).unwrap();
    assert!(remote
        .iter()
        .all(|p| p.provenance == Provenance::Remote { addr: addr_a.clone() }));
    assert_identical("remote vs local", &remote, &local);

    let hosts = vec![addr_a.clone(), addr_b.clone()];
    let federated_backend = FederatedPlanner::connect(&hosts).unwrap();
    let federated = federated_backend.plan_many(&reqs).unwrap();
    assert!(federated
        .iter()
        .all(|p| matches!(p.provenance, Provenance::Federated { shard } if shard < 2)));
    assert_identical("federated vs local", &federated, &local);

    // Single-point plan through every backend, same story.
    let one = &reqs[0];
    let solo_local = LocalPlanner.plan(one).unwrap();
    let solo_remote = remote_backend.plan(one).unwrap();
    let solo_fed = federated_backend.plan(one).unwrap();
    assert_identical(
        "solo remote vs local",
        std::slice::from_ref(&solo_remote),
        std::slice::from_ref(&solo_local),
    );
    assert_identical(
        "solo federated vs local",
        std::slice::from_ref(&solo_fed),
        std::slice::from_ref(&solo_local),
    );

    // Kill host A: shards that lived there must fail over to host B and
    // the sweep must still be bit-identical to the local control.
    RemotePlanner::connect(&addr_a).unwrap().shutdown().unwrap();
    handle_a.join().unwrap();
    // Pin down a request that *homes* on the dead shard, so the retry
    // path is provably exercised rather than hash-luck avoided.
    let homed_on_dead = (1..200usize)
        .map(|bs| PlanRequest::named("dqn_cartpole").unwrap().with_batch(bs))
        .find(|r| federated_backend.shard_for(r) == 0)
        .expect("some batch must hash to shard 0");
    let served = federated_backend.plan(&homed_on_dead).unwrap();
    assert_eq!(
        served.provenance,
        Provenance::Federated { shard: 1 },
        "a request homed on the dead host must be served by the survivor"
    );
    let mut reqs_down = reqs.clone();
    reqs_down.push(homed_on_dead.clone());
    let after_failover = federated_backend.plan_many(&reqs_down).unwrap();
    assert_identical(
        "federated (one host down) vs local",
        &after_failover[..reqs.len()],
        &local,
    );
    assert_identical(
        "failed-over point vs local",
        &after_failover[reqs.len()..],
        std::slice::from_ref(&LocalPlanner.plan(&homed_on_dead).unwrap()),
    );
    // Everything was served by the surviving shard (index 1).
    assert!(after_failover
        .iter()
        .all(|p| p.provenance == Provenance::Federated { shard: 1 }));
    // Single plans fail over too.
    let solo_after = federated_backend.plan(one).unwrap();
    assert_eq!(solo_after.provenance, Provenance::Federated { shard: 1 });
    assert_identical(
        "solo federated (one host down) vs local",
        std::slice::from_ref(&solo_after),
        std::slice::from_ref(&solo_local),
    );

    RemotePlanner::connect(&addr_b).unwrap().shutdown().unwrap();
    handle_b.join().unwrap();

    // With every host gone the federation reports failure, not a hang.
    assert!(federated_backend.plan_many(&reqs).is_err());
    assert!(federated_backend.plan(one).is_err());
}

/// Federation v2: a dead host's remaining batch re-shards **across all
/// survivors** (balanced round-robin retry chunks), not onto a single
/// adoptive host — and the merged results stay in request order,
/// bit-identical to local plans.
#[test]
fn dead_host_remainder_rebalances_across_all_survivors() {
    let (addr_a, handle_a) = boot(2);
    let (addr_b, handle_b) = boot(2);
    let (addr_c, handle_c) = boot(2);
    let hosts = vec![addr_a.clone(), addr_b.clone(), addr_c.clone()];
    let fed = FederatedPlanner::connect(&hosts).unwrap();
    // Six points all homed on shard 0, so killing host 0 hands the whole
    // batch to the fail-over path.
    let homed: Vec<PlanRequest> = (1..400usize)
        .map(|bs| PlanRequest::named("dqn_cartpole").unwrap().with_batch(bs))
        .filter(|r| fed.shard_for(r) == 0)
        .take(6)
        .collect();
    assert_eq!(homed.len(), 6, "expected six shard-0 points below batch 400");
    RemotePlanner::connect(&addr_a).unwrap().shutdown().unwrap();
    handle_a.join().unwrap();

    let outcomes = fed.plan_many(&homed).unwrap();
    // Merged order unchanged: outcome i is request i, bit-identical to
    // the local control.
    let local = LocalPlanner.plan_many(&homed).unwrap();
    assert_identical("re-sharded vs local", &outcomes, &local);
    // The remainder spread across BOTH survivors, balanced 3/3 — not one
    // survivor absorbing all six.
    let mut counts = [0usize; 3];
    for o in &outcomes {
        match o.provenance {
            Provenance::Federated { shard } => counts[shard] += 1,
            ref p => panic!("unexpected provenance {p:?}"),
        }
    }
    assert_eq!(counts, [0, 3, 3], "round-robin must balance the dead host's remainder");
    // Round-robin is positional: pending requests alternate survivors.
    for (i, o) in outcomes.iter().enumerate() {
        let expect = [1, 2][i % 2];
        assert_eq!(o.provenance, Provenance::Federated { shard: expect }, "point {i}");
    }

    for addr in [&addr_b, &addr_c] {
        RemotePlanner::connect(addr).unwrap().shutdown().unwrap();
    }
    handle_b.join().unwrap();
    handle_c.join().unwrap();
}

/// Errors (unknown combos, inexpressible customized combos) surface
/// through every backend as reported errors, not panics or misplans.
#[test]
fn bad_requests_error_uniformly_across_backends() {
    let (addr, handle) = boot(2);

    // Unknown combo: rejected at request construction.
    assert!(PlanRequest::named("dqn_tetris").is_err());

    // Customized (non-registry) combo: local plans it, remote/federated
    // refuse to lower it onto the wire instead of planning the wrong net.
    let mut custom = apdrl::coordinator::combo("dqn_cartpole");
    custom.net = apdrl::graph::NetSpec::mlp(&[4, 160, 160, 2]);
    let req = PlanRequest::new(custom, 32, true);
    assert!(LocalPlanner.plan(&req).is_ok());
    let remote = RemotePlanner::connect(&addr).unwrap();
    let e = remote.plan(&req).unwrap_err();
    assert!(format!("{e}").contains("LocalPlanner"), "{e}");
    let fed = FederatedPlanner::connect(&[addr.clone()]).unwrap();
    assert!(fed.plan_many(std::slice::from_ref(&req)).is_err());

    // Zero batch is rejected by every backend.
    let zero = PlanRequest::named("dqn_cartpole").unwrap().with_batch(0);
    assert!(LocalPlanner.plan(&zero).is_err());
    assert!(remote.plan(&zero).is_err());

    remote.shutdown().unwrap();
    handle.join().unwrap();
}

/// The federated sweep shards deterministically by plan key: the same
/// grid twice gives the same shard assignment, and the second pass rides
/// each daemon's warm cache.
#[test]
fn federated_sharding_is_stable_and_cache_affine() {
    let (addr_a, handle_a) = boot(2);
    let (addr_b, handle_b) = boot(2);
    let fed = FederatedPlanner::connect(&[addr_a.clone(), addr_b.clone()]).unwrap();
    let reqs: Vec<PlanRequest> = [34usize, 50, 66, 82]
        .iter()
        .map(|&bs| PlanRequest::named("dqn_cartpole").unwrap().with_batch(bs))
        .collect();
    let first = fed.plan_many(&reqs).unwrap();
    let second = fed.plan_many(&reqs).unwrap();
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.provenance, b.provenance, "shard assignment must be stable");
        assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
    }
    assert!(
        second.iter().all(|p| p.cache_hit),
        "stable sharding must make the second pass all daemon-cache hits"
    );
    RemotePlanner::connect(&addr_a).unwrap().shutdown().unwrap();
    RemotePlanner::connect(&addr_b).unwrap().shutdown().unwrap();
    handle_a.join().unwrap();
    handle_b.join().unwrap();
}
