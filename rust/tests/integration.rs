//! Integration tests across the three layers: PJRT runtime ↔ AOT
//! artifacts ↔ coordinator.  These need the `pjrt` feature (xla
//! bindings) and `make artifacts` to have run (they are skipped
//! gracefully without artifacts, but `make test` builds first).

#![cfg(feature = "pjrt")]

use apdrl::coordinator::{combo, static_phase, train_combo, TrainLimits};
use apdrl::runtime::executor::{literal_f32, scalar_of, to_vec_f32};
use apdrl::runtime::Runtime;
use apdrl::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match Runtime::new(dir) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping integration test (run `make artifacts`): {e:#}");
            None
        }
    }
}

/// The gemm artifacts compute what they claim: cross-check the Pallas
/// kernel's HLO against a host matmul.
#[test]
fn gemm_artifact_matches_host_matmul() {
    let Some(mut rt) = runtime() else { return };
    let exe = rt.load("gemm_64_fp32").unwrap();
    let n = 64usize;
    let mut rng = Rng::new(42);
    let a: Vec<f32> = (0..n * n).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
    let (la, lb) = (literal_f32(&a, &[n, n]).unwrap(), literal_f32(&b, &[n, n]).unwrap());
    let outs = exe.run(&[&la, &lb]).unwrap();
    let got = to_vec_f32(&outs[0]).unwrap();
    // host reference
    let mut expect = vec![0.0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                expect[i * n + j] += aik * b[k * n + j];
            }
        }
    }
    for (g, e) in got.iter().zip(&expect) {
        assert!((g - e).abs() < 1e-3, "{g} vs {e}");
    }
}

/// bf16 gemm artifact differs from fp32 by a bf16-sized relative error —
/// the precision emulation survives the AOT → PJRT round trip.
#[test]
fn gemm_bf16_artifact_rounds() {
    let Some(mut rt) = runtime() else { return };
    let f32_exe = rt.load("gemm_64_fp32").unwrap();
    let bf16_exe = rt.load("gemm_64_bf16").unwrap();
    let n = 64usize;
    let mut rng = Rng::new(7);
    let a: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
    let (la, lb) = (literal_f32(&a, &[n, n]).unwrap(), literal_f32(&b, &[n, n]).unwrap());
    let args = [&la, &lb];
    let full = to_vec_f32(&f32_exe.run(&args).unwrap()[0]).unwrap();
    let quant = to_vec_f32(&bf16_exe.run(&args).unwrap()[0]).unwrap();
    assert_ne!(full, quant, "bf16 artifact must actually round");
    // bf16 rel. error 2⁻⁸ per product accumulates over K=64 f32 adds:
    // tolerance ≈ √K · 2⁻⁸ · |a||b| on N(0,1) operands.
    for (f, q) in full.iter().zip(&quant) {
        assert!((f - q).abs() <= 0.05 * f.abs().max(2.0), "{f} vs {q}");
    }
}

/// One DQN train-step artifact invocation: loss finite, found_inf clear,
/// params actually updated, and the step is deterministic.
#[test]
fn dqn_train_step_executes_and_updates() {
    let Some(mut rt) = runtime() else { return };
    let exe = rt.load("dqn_cartpole_mixed_train").unwrap();
    let shapes = exe.spec().param_shapes();
    let mut rng = Rng::new(3);
    let params = apdrl::drl::ParamSet::init(&shapes, &mut rng).unwrap();
    let target = params.clone_literals();
    let opt = apdrl::drl::ParamSet::opt_state(&shapes).unwrap();
    let bs = 64usize;
    let s: Vec<f32> = (0..bs * 4).map(|_| rng.uniform_in(-0.1, 0.1) as f32).collect();
    let a: Vec<i32> = (0..bs).map(|_| rng.below(2) as i32).collect();
    let r: Vec<f32> = (0..bs).map(|_| 1.0).collect();
    let done = vec![0.0f32; bs];
    let run_once = || {
        let scratch = [
            literal_f32(&s, &[bs, 4]).unwrap(),
            apdrl::runtime::executor::literal_i32(&a, &[bs]).unwrap(),
            literal_f32(&r, &[bs]).unwrap(),
            literal_f32(&s, &[bs, 4]).unwrap(),
            literal_f32(&done, &[bs]).unwrap(),
            literal_f32(&[1024.0], &[]).unwrap(),
        ];
        let mut inputs: Vec<&xla::Literal> = params.tensors.iter().collect();
        inputs.extend(target.iter());
        inputs.extend(opt.iter());
        inputs.extend(scratch.iter());
        exe.run(&inputs).unwrap()
    };
    let outs1 = run_once();
    let outs2 = run_once();
    let loss = scalar_of(&outs1[outs1.len() - 2]).unwrap();
    let found_inf = scalar_of(&outs1[outs1.len() - 1]).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(found_inf, 0.0);
    // params changed
    let w0_new = to_vec_f32(&outs1[0]).unwrap();
    let w0_old = to_vec_f32(&params.tensors[0]).unwrap();
    assert_ne!(w0_new, w0_old);
    // deterministic
    assert_eq!(w0_new, to_vec_f32(&outs2[0]).unwrap());
}

/// Ridiculous loss scale → found_inf set and update skipped (the Fig 9
/// contract between the artifact and the L3 LossScaler).
#[test]
fn dqn_train_step_overflow_skips_update() {
    let Some(mut rt) = runtime() else { return };
    let exe = rt.load("dqn_cartpole_mixed_train").unwrap();
    let shapes = exe.spec().param_shapes();
    let mut rng = Rng::new(5);
    let params = apdrl::drl::ParamSet::init(&shapes, &mut rng).unwrap();
    let opt = apdrl::drl::ParamSet::opt_state(&shapes).unwrap();
    let bs = 64usize;
    let s: Vec<f32> = (0..bs * 4).map(|_| rng.normal() as f32).collect();
    let a = vec![0i32; bs];
    let r = vec![1e30f32; bs]; // absurd rewards → overflowing grads
    let done = vec![0.0f32; bs];
    let scratch = [
        literal_f32(&s, &[bs, 4]).unwrap(),
        apdrl::runtime::executor::literal_i32(&a, &[bs]).unwrap(),
        literal_f32(&r, &[bs]).unwrap(),
        literal_f32(&s, &[bs, 4]).unwrap(),
        literal_f32(&done, &[bs]).unwrap(),
        literal_f32(&[65536.0], &[]).unwrap(),
    ];
    let mut inputs: Vec<&xla::Literal> = params.tensors.iter().collect();
    inputs.extend(params.tensors.iter());
    inputs.extend(opt.iter());
    inputs.extend(scratch.iter());
    let outs = exe.run(&inputs).unwrap();
    let found_inf = scalar_of(&outs[outs.len() - 1]).unwrap();
    assert_eq!(found_inf, 1.0);
    let w0_new = to_vec_f32(&outs[0]).unwrap();
    let w0_old = to_vec_f32(&params.tensors[0]).unwrap();
    assert_eq!(w0_new, w0_old, "update must be skipped on overflow");
}

/// Short end-to-end training run: the agent must clearly beat the random
/// policy on CartPole within a few thousand PJRT-executed steps.
#[test]
fn cartpole_training_improves_over_random() {
    let Some(mut rt) = runtime() else { return };
    let c = combo("dqn_cartpole");
    let limits = TrainLimits { max_env_steps: 6_000, max_episodes: 400 };
    let mut backend = apdrl::exec::PjrtBackend::new(&mut rt, "mixed");
    let result = train_combo(&mut backend, &c, 11, limits, false).unwrap();
    let random_baseline = 25.0; // random CartPole episodes last ~20-25 steps
    let late = result.metrics.converged_reward(30);
    assert!(
        late > random_baseline * 1.8,
        "training did not improve: converged {late} vs random {random_baseline}"
    );
    assert!(result.metrics.train_steps > 1_000);
}

/// Every convergence combo has loadable artifacts for all three modes,
/// and the rust-side combo registry matches the python-side shapes.
#[test]
fn all_artifacts_load_and_shapes_match() {
    let Some(mut rt) = runtime() else { return };
    for name in apdrl::coordinator::COMBO_NAMES {
        for mode in ["fp32", "mixed", "bf16"] {
            for kind in ["train", "act"] {
                let art = format!("{name}_{mode}_{kind}");
                let exe = rt.load(&art).unwrap_or_else(|e| panic!("loading {art}: {e:#}"));
                assert!(!exe.spec().inputs.is_empty());
            }
        }
        // shape agreement: python param_shapes vs rust NetSpec
        let c = combo(name);
        let train = rt.load(&format!("{name}_mixed_train")).unwrap();
        let total_py: usize = if c.algo == apdrl::graph::Algo::Ddpg {
            // actor_shapes + critic_shapes
            let s = train.spec();
            let count = |key: &str| {
                s.meta
                    .get(key)
                    .and_then(|v| v.as_arr())
                    .map(|a| {
                        a.iter()
                            .map(|sh| {
                                sh.as_arr()
                                    .unwrap()
                                    .iter()
                                    .map(|d| d.as_usize().unwrap())
                                    .product::<usize>()
                            })
                            .sum::<usize>()
                    })
                    .unwrap_or(0)
            };
            count("actor_shapes") + count("critic_shapes")
        } else {
            train
                .spec()
                .param_shapes()
                .iter()
                .map(|sh| sh.iter().product::<usize>())
                .sum()
        };
        let rust_weights = c.net.weight_elems();
        // A2C/PPO add value nets / heads / log_std on top of the actor
        // net; DQN matches exactly.
        assert!(
            total_py >= rust_weights,
            "{name}: python params {total_py} < rust net weights {rust_weights}"
        );
    }
}

/// The static phase and the artifact precision modes agree: the ILP's
/// policy for each convergence combo maps onto an artifact that exists.
#[test]
fn static_plan_mode_has_matching_artifact() {
    let Some(rt) = runtime() else { return };
    for name in apdrl::coordinator::COMBO_NAMES {
        let c = combo(name);
        let plan = static_phase(&c, c.batch, true);
        let mode = plan.policy.artifact_mode();
        let art = format!("{name}_{mode}_train");
        assert!(
            rt.manifest().get(&art).is_ok(),
            "{name}: plan wants mode {mode} but artifact {art} missing"
        );
    }
}
