//! Self-calibrating cost model suite: `APDRL_CALIB` persistence is
//! bit-exact, stale schemas drop to cold start, the planner prices PS
//! costs from measurements exactly when a covering table is active —
//! and tracing those measurements can never perturb bit-exactness
//! (the kernel-equivalence and training-identity contracts hold with a
//! recorder armed and a live bus subscriber attached).
//!
//! These tests mutate process environment (`APDRL_CALIB`), so every
//! test in this binary serializes on one lock — the env is process
//! state, and `cargo test` runs tests on concurrent threads.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};

use apdrl::coordinator::{
    combo, static_phase, train_combo_actors, PlanOutcome, PlanRequest, TrainLimits,
};
use apdrl::exec::{CpuBackend, Pool, Tensor};
use apdrl::graph::{build_train_graph, LayerKind};
use apdrl::obs::trace::{self, Kernel};
use apdrl::profile::calib::{active_fingerprint, with_global};
use apdrl::profile::{CalibPoint, CalibrationTable, ENV_CALIB};
use apdrl::util::Rng;

fn env_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn temp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("apdrl_calib_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

/// Wide-dynamic-range values so reordered f32 summation would actually
/// change bits (mirrors the helper in `tests/kernels.rs`).
fn rand_tensor(rng: &mut Rng, rows: usize, cols: usize) -> Tensor {
    let data = (0..rows * cols)
        .map(|_| {
            let mag = 2.0f64.powi((rng.below(17) as i32) - 8);
            (rng.normal() * mag) as f32
        })
        .collect();
    Tensor::from_vec(data, &[rows, cols])
}

#[test]
fn apdrl_calib_round_trip_is_bit_exact() {
    let _env = env_lock();
    let mut table = CalibrationTable::new();
    // Deliberately awkward bits: the smallest subnormal, a repeating
    // binary fraction, and a huge magnitude only hex storage keeps.
    table.insert_point(
        "gemm_nn",
        4,
        CalibPoint { work: 0.1 + 0.2, ns: f64::from_bits(1), count: 7 },
    );
    table.insert_point("gemm_nn", 4, CalibPoint { work: 12_345.0, ns: 1.0 / 3.0, count: 2 });
    table.insert_point("adam_step", 1, CalibPoint { work: 1e300, ns: 7e-12, count: 1 });

    let path = temp_path("roundtrip.json");
    table.save(&path).unwrap();
    let back = CalibrationTable::load(&path).expect("current-schema file must load");
    assert_eq!(back, table);
    // The fingerprint hashes raw float bits, so equality here is the
    // bit-exactness proof (not just approximate equality).
    assert_eq!(back.fingerprint(), table.fingerprint());

    // The same file through the `APDRL_CALIB` global accessor.
    std::env::set_var(ENV_CALIB, &path);
    assert_eq!(active_fingerprint().as_deref(), Some(table.fingerprint().as_str()));
    with_global(|t| {
        let t = t.expect("env names a loadable table");
        assert_eq!(t.entries(), table.entries());
        assert_eq!(t.points(), table.points());
    });
    std::env::remove_var(ENV_CALIB);
    assert!(active_fingerprint().is_none(), "unset env is a cold start");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn stale_schema_calib_file_is_a_cold_start() {
    let _env = env_lock();
    let path = temp_path("stale.json");
    std::fs::write(&path, "{\"schema\":99.0,\"entries\":[]}\n").unwrap();
    assert!(CalibrationTable::load(&path).is_none(), "wrong schema must not parse");

    std::env::set_var(ENV_CALIB, &path);
    assert!(active_fingerprint().is_none());
    let plan = static_phase(&combo("dqn_cartpole"), 40, true);
    assert!(
        plan.profiles.iter().all(|p| !p.ps_measured),
        "a stale table must leave every node on the analytic model"
    );
    std::env::remove_var(ENV_CALIB);
    let _ = std::fs::remove_file(&path);
}

/// The acceptance scenario: with `APDRL_CALIB` naming a table that
/// covers the combo's shapes, `static_phase` prices every node's CPU
/// cost from the table (bit-identical to a direct lookup) and the
/// `PlanOutcome` reports the calibrated steps; without the env var the
/// same plan is fully analytic with zero calibrated steps.
#[test]
fn planner_prices_cpu_costs_from_measurements_only_with_a_table() {
    let _env = env_lock();
    let c = combo("dqn_cartpole");
    let batch = 52;
    let dag = build_train_graph(&c.train_spec(batch));

    // Cover every node shape exactly: one calibration point per
    // distinct work value, at a deliberately non-analytic cost.
    let mut gemm_works: BTreeSet<u64> = BTreeSet::new();
    let mut elem_works: BTreeSet<u64> = BTreeSet::new();
    for node in &dag.nodes {
        match node.kind {
            LayerKind::Mm { m, k, n } => {
                gemm_works.insert((m * k * n) as u64);
            }
            LayerKind::Elementwise { elems } | LayerKind::Reduce { elems } => {
                elem_works.insert(elems as u64);
            }
        }
    }
    let mut table = CalibrationTable::new();
    for &w in &gemm_works {
        table.insert_point(
            "gemm_nn",
            1,
            CalibPoint { work: w as f64, ns: w as f64 * 5.0, count: 8 },
        );
    }
    for &w in &elem_works {
        table.insert_point(
            "round_slice",
            1,
            CalibPoint { work: w as f64, ns: w as f64 * 3.0, count: 8 },
        );
    }
    let path = temp_path("acceptance.json");
    table.save(&path).unwrap();

    std::env::set_var(ENV_CALIB, &path);
    let calibrated = static_phase(&c, batch, true);
    assert!(
        calibrated.profiles.iter().all(|p| p.ps_measured),
        "the table covers every shape, so every node must price as measured"
    );
    let threads = Pool::global().threads();
    let mut diverged = 0;
    for (node, p) in dag.nodes.iter().zip(&calibrated.profiles) {
        let (kernel, work, thr) = match node.kind {
            LayerKind::Mm { m, k, n } => (Kernel::GemmNn, (m * k * n) as f64, threads),
            LayerKind::Elementwise { elems } | LayerKind::Reduce { elems } => {
                (Kernel::RoundSlice, elems as f64, 1)
            }
        };
        let expect = table.lookup_us(kernel, thr, work).expect("shape is covered");
        assert_eq!(
            p.ps_latency_us.to_bits(),
            expect.to_bits(),
            "node {}: planner CPU cost must equal the table lookup",
            node.name
        );
        if p.ps_latency_us.to_bits() != p.ps_modeled_us.to_bits() {
            diverged += 1;
        }
    }
    assert!(diverged > 0, "measured costs must actually differ from the analytic model");

    let req = PlanRequest::new(c.clone(), batch, true);
    let outcome = PlanOutcome::from_static(&calibrated, &req);
    assert!(outcome.calib_steps > 0, "calibrated plans report their measured steps");
    assert_eq!(outcome.calib_fingerprint, table.fingerprint());
    assert!(outcome.schedule.iter().any(|s| s.measured));
    for s in &outcome.schedule {
        let p = &calibrated.profiles[s.node];
        assert_eq!(s.measured, p.ps_measured);
        assert_eq!(s.cpu_us.to_bits(), p.ps_latency_us.to_bits());
        assert_eq!(s.modeled_us.to_bits(), p.ps_modeled_us.to_bits());
    }

    // Same request without the table: pure analytic model.
    std::env::remove_var(ENV_CALIB);
    let cold = static_phase(&c, batch, true);
    assert!(cold.profiles.iter().all(|p| !p.ps_measured));
    for p in &cold.profiles {
        assert_eq!(
            p.ps_latency_us.to_bits(),
            p.ps_modeled_us.to_bits(),
            "cold-start CPU cost is the analytic prediction itself"
        );
    }
    let outcome = PlanOutcome::from_static(&cold, &req);
    assert_eq!(outcome.calib_steps, 0);
    assert_eq!(outcome.calib_err_pct.to_bits(), 0.0f64.to_bits());
    assert!(outcome.calib_fingerprint.is_empty());
    let _ = std::fs::remove_file(&path);
}

/// Tracing observes, never mutates: with a recorder armed *and* a live
/// bus subscriber attached, the GEMM kernels stay bit-identical to the
/// naive reference at 1 and 8 threads, and a short training run
/// produces bit-identical rewards/FSM state to an untraced run.
#[test]
fn bit_identity_survives_tracing_with_a_live_subscriber() {
    let _env = env_lock();
    let mut sub = apdrl::obs::global().subscribe();
    let rec = trace::record();
    assert!(trace::active());

    // Kernel equivalence with spans hot.
    let mut rng = Rng::new(0xCA11B);
    let a = rand_tensor(&mut rng, 65, 33);
    let b = rand_tensor(&mut rng, 33, 29);
    let want = a.matmul_naive(&b);
    for threads in [1usize, 8] {
        let pool = Arc::new(Pool::new(threads));
        let got = a.matmul_with(&b, &pool);
        for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "elem {i} @ {threads} threads");
        }
    }

    // A traced + subscribed training run vs the plain one.
    let limits = TrainLimits { max_env_steps: 500, max_episodes: 40 };
    let traced =
        train_combo_actors(&mut CpuBackend::fp32(), &combo("dqn_cartpole"), 11, limits, 1, false)
            .unwrap();
    assert!(
        trace::snapshot_aggregate().iter().any(|r| r.kernel == Kernel::GemmNn),
        "armed spans must have aggregated GEMM samples"
    );
    let drained = sub.drain();
    assert!(
        drained.events.iter().any(|e| e.kind == "trace.kernel"),
        "a live subscriber must see trace.kernel events"
    );
    drop(rec);

    let limits = TrainLimits { max_env_steps: 500, max_episodes: 40 };
    let plain =
        train_combo_actors(&mut CpuBackend::fp32(), &combo("dqn_cartpole"), 11, limits, 1, false)
            .unwrap();
    assert_eq!(traced.metrics.env_steps, plain.metrics.env_steps);
    assert_eq!(traced.metrics.episode_rewards.len(), plain.metrics.episode_rewards.len());
    for (t, p) in traced.metrics.episode_rewards.iter().zip(&plain.metrics.episode_rewards) {
        assert_eq!(t.to_bits(), p.to_bits(), "tracing must not perturb rewards");
    }
    assert_eq!(traced.metrics.scale_transitions, plain.metrics.scale_transitions);
    assert_eq!(
        traced.metrics.final_loss_scale.to_bits(),
        plain.metrics.final_loss_scale.to_bits()
    );
}
