//! Integration tests for the planning server: boot a daemon on an
//! ephemeral port, drive it with concurrent `plan`/`sweep` clients,
//! assert remote schedules are *byte-identical* to the in-process
//! planner's, and exercise the malformed-request and protocol-version
//! error paths.  Everything runs on the default (non-`pjrt`) feature
//! set over loopback TCP.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use apdrl::coordinator::{combo, static_phase};
use apdrl::server::{RemotePlanner, Server, PROTOCOL_VERSION};
use apdrl::util::json::Json;

/// Boot a server on an ephemeral loopback port; returns its address and
/// the thread that runs it (joined after `shutdown`).
fn boot(workers: usize) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", workers).expect("ephemeral bind must work");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run().expect("server run must not error"));
    (addr, handle)
}

/// The acceptance scenario: remote plans/sweeps equal the in-process
/// planner bit for bit, concurrent clients are serviced, the second
/// identical sweep is served from the shared cache (stats verb shows
/// hits), and shutdown stops the daemon cleanly.
#[test]
fn remote_plans_are_byte_identical_and_cache_is_shared() {
    let (addr, handle) = boot(3);

    // Concurrent clients: two sweeps over the same small grid plus a
    // single-point plan, all in flight together.
    let combos = vec!["dqn_cartpole".to_string(), "a2c_invpend".to_string()];
    let batches = [36usize, 52];
    let sweep_a = {
        let (addr, combos) = (addr.clone(), combos.clone());
        std::thread::spawn(move || {
            RemotePlanner::connect(&addr).unwrap().sweep(&combos, &batches, true).unwrap()
        })
    };
    let sweep_b = {
        let (addr, combos) = (addr.clone(), combos.clone());
        std::thread::spawn(move || {
            RemotePlanner::connect(&addr).unwrap().sweep(&combos, &batches, true).unwrap()
        })
    };
    let solo = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            RemotePlanner::connect(&addr).unwrap().plan_named("ddpg_mntncar", 44, true).unwrap()
        })
    };
    let plans_a = sweep_a.join().unwrap();
    let plans_b = sweep_b.join().unwrap();
    let remote_solo = solo.join().unwrap();

    // Remote vs in-process: identical grids, identical optima.  (The
    // *value* of the optimum is unique, so makespan bits always agree;
    // full schedule byte-identity is asserted below on the
    // cache-mediated repeat sweep, where it is deterministic even if
    // the two concurrent first solves raced on a symmetric tie.)
    assert_eq!(plans_a.len(), combos.len() * batches.len());
    for (i, remote) in plans_a.iter().enumerate() {
        let c = combo(&combos[i / batches.len()]);
        let bs = batches[i % batches.len()];
        let local = static_phase(&c, bs, true);
        assert_eq!(remote.combo, c.name);
        assert_eq!(remote.batch, bs);
        assert_eq!(
            remote.makespan_us.to_bits(),
            local.schedule.makespan_us.to_bits(),
            "{} bs={bs}: remote and local makespans must be bit-identical",
            c.name
        );
        assert_eq!(remote.schedule.len(), local.schedule.entries.len());
        assert_eq!(remote.assignment.len(), local.solution.assignment.len());
    }
    // The two concurrent sweeps must agree on every optimum.
    for (a, b) in plans_a.iter().zip(&plans_b) {
        assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
    }
    let local_solo = static_phase(&combo("ddpg_mntncar"), 44, true);
    assert_eq!(
        remote_solo.makespan_us.to_bits(),
        local_solo.schedule.makespan_us.to_bits()
    );

    // Second identical sweep on a fresh connection: every point now
    // comes out of the shared cache, and the stats verb must say so.
    // These plans are byte-identical to the in-process planner's — same
    // cache entry, same deterministic schedule evaluation, schedule
    // times surviving the wire bit-for-bit.
    let client = RemotePlanner::connect(&addr).unwrap();
    let replans = client.sweep(&combos, &batches, true).unwrap();
    assert!(
        replans.iter().all(|p| p.cache_hit && p.explored == 0),
        "second identical sweep must be all cache hits"
    );
    for (i, remote) in replans.iter().enumerate() {
        let c = combo(&combos[i / batches.len()]);
        let bs = batches[i % batches.len()];
        let local = static_phase(&c, bs, true);
        assert!(local.cache_hit, "local control must read the same shared cache");
        for (r, l) in remote.schedule.iter().zip(&local.schedule.entries) {
            assert_eq!(r.node, l.node);
            assert_eq!(r.component, l.component.name());
            assert_eq!(r.start_us.to_bits(), l.start_us.to_bits());
            assert_eq!(r.finish_us.to_bits(), l.finish_us.to_bits());
        }
        for (r, l) in remote.assignment.iter().zip(&local.solution.assignment) {
            assert_eq!(r.0, l.component.name());
            assert_eq!(r.1, l.candidate);
        }
        assert_eq!(remote.step_time_us().to_bits(), local.step_time_us().to_bits());
    }
    let stats = client.stats().unwrap();
    let hits = stats
        .get("cache")
        .and_then(|c| c.get("hits"))
        .and_then(Json::as_usize)
        .expect("stats must carry cache.hits");
    assert!(hits > 0, "stats must report cache hits after the repeat sweep");
    let served = stats.get("plans_served").and_then(Json::as_usize).unwrap();
    assert!(served >= 3 * combos.len() * batches.len(), "all sweep points counted");

    // cache_flush empties the shared cache; the next sweep re-solves.
    let flushed = client.cache_flush().unwrap();
    assert!(flushed > 0, "flush must report evicted entries");

    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// Malformed requests and version mismatches get error responses on a
/// connection that stays usable; the protocol never kills the daemon.
#[test]
fn malformed_and_mismatched_requests_error_without_killing_the_connection() {
    let (addr, handle) = boot(2);
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut ask = |line: &str| -> Json {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        Json::parse(buf.trim()).expect("server must always answer valid JSON")
    };
    let err_of = |resp: &Json| -> String {
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        resp.get("error").and_then(Json::as_str).unwrap_or_default().to_string()
    };

    // Not JSON at all.
    let resp = ask("this is not json");
    assert!(err_of(&resp).contains("bad request"), "{resp}");
    // Valid JSON, wrong protocol version — rejected before the verb.
    let resp = ask(&format!(r#"{{"v":{},"verb":"stats"}}"#, PROTOCOL_VERSION + 40));
    assert!(err_of(&resp).contains("protocol version mismatch"), "{resp}");
    // Missing version field.
    let resp = ask(r#"{"verb":"stats"}"#);
    assert!(err_of(&resp).contains("missing protocol version"), "{resp}");
    // Unknown verb.
    let resp = ask(r#"{"v":2,"verb":"transmogrify"}"#);
    assert!(err_of(&resp).contains("unknown verb"), "{resp}");
    // Unknown combo: a *planning* error, still a clean protocol answer.
    let resp = ask(r#"{"v":2,"verb":"plan","combo":"dqn_tetris","batch":8}"#);
    assert!(err_of(&resp).contains("unknown combo"), "{resp}");
    // Zero batch.
    let resp = ask(r#"{"v":2,"verb":"plan","combo":"dqn_cartpole","batch":0}"#);
    assert!(err_of(&resp).contains("batch"), "{resp}");

    // After all those errors the same connection still serves requests.
    let resp = ask(r#"{"v":2,"verb":"stats"}"#);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
    let errors = resp
        .get("stats")
        .and_then(|s| s.get("errors"))
        .and_then(Json::as_usize)
        .unwrap();
    assert!(errors >= 6, "every bad request must be counted, got {errors}");

    // Tidy up the raw connection (both fd clones) before stopping the
    // daemon; per-request scheduling means it could not block shutdown,
    // but an explicit close keeps the teardown deterministic.
    drop(reader);
    drop(stream);
    RemotePlanner::connect(&addr).unwrap().shutdown().unwrap();
    handle.join().unwrap();
}

/// Regression for the sweep-duplication satellite: a `sweep` request
/// naming the same combo twice must NOT replan the repeated (combo,
/// batch) pairs — the handler dedupes by plan key, so every duplicate
/// point reports `explored == 0` (a memoized copy of the first), with a
/// bit-identical schedule.
#[test]
fn duplicate_combos_in_one_sweep_request_are_not_replanned() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let combos = vec![
        "ddpg_mntncar".to_string(),
        "ddpg_mntncar".to_string(),
        "dqn_cartpole".to_string(),
        "ddpg_mntncar".to_string(),
    ];
    let batches = [57usize];
    let plans = client.sweep(&combos, &batches, true).unwrap();
    assert_eq!(plans.len(), combos.len());
    for dup in [&plans[1], &plans[3]] {
        assert_eq!(dup.combo, "ddpg_mntncar");
        assert_eq!(
            dup.explored, 0,
            "repeated (combo, batch) point in one request must not re-search"
        );
        assert!(dup.cache_hit, "repeated point must be a memoized copy");
        assert_eq!(dup.makespan_us.to_bits(), plans[0].makespan_us.to_bits());
        for (a, b) in dup.schedule.iter().zip(&plans[0].schedule) {
            assert_eq!(a.start_us.to_bits(), b.start_us.to_bits());
            assert_eq!(a.finish_us.to_bits(), b.finish_us.to_bits());
        }
        assert_eq!(dup.assignment, plans[0].assignment);
    }
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// FP32 vs quantized travel the wire as distinct plans, and the remote
/// side sees the same precision-dependent formats the local one does.
#[test]
fn remote_respects_precision_mode() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let quant = client.plan_named("ddpg_lunar", 96, true).unwrap();
    let fp32 = client.plan_named("ddpg_lunar", 96, false).unwrap();
    assert!(quant.quantized && !fp32.quantized);
    assert!(
        fp32.schedule.iter().all(|e| e.format == "FP32"),
        "FP32 control must not carry reduced-precision formats"
    );
    let local_q = static_phase(&combo("ddpg_lunar"), 96, true);
    assert_eq!(quant.makespan_us.to_bits(), local_q.schedule.makespan_us.to_bits());
    let local_f = static_phase(&combo("ddpg_lunar"), 96, false);
    assert_eq!(fp32.makespan_us.to_bits(), local_f.schedule.makespan_us.to_bits());
    client.shutdown().unwrap();
    handle.join().unwrap();
}
