//! Integration tests for the planning server: boot a daemon on an
//! ephemeral port, drive it with concurrent `plan`/`sweep` clients,
//! assert remote schedules are *byte-identical* to the in-process
//! planner's, and exercise the malformed-request and protocol-version
//! error paths.  The protocol-v3 training verbs get the same
//! treatment: `train` streams a job whose final metrics are
//! bit-identical to the in-process trainer's, `jobs`/`cancel` manage
//! the scheduler over the wire, a shutdown drains running jobs to a
//! hand-off checkpoint, and a two-daemon fail-over completes a job on
//! the survivor bit-exactly.  Everything runs on the default
//! (non-`pjrt`) feature set over loopback TCP.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use apdrl::coordinator::metrics::RunMetrics;
use apdrl::coordinator::{
    combo, static_phase, train_combo_actors, LocalPlanner, PlanRequest, Planner, TrainLimits,
};
use apdrl::exec::CpuBackend;
use apdrl::server::{
    Journal, RemotePlanner, RemoteTrainer, Server, TrainSubmission, PROTOCOL_VERSION,
};
use apdrl::util::json::{hex_f64s, Json};

/// Boot a server on an ephemeral loopback port; returns its address and
/// the thread that runs it (joined after `shutdown`).
fn boot(workers: usize) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", workers).expect("ephemeral bind must work");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run().expect("server run must not error"));
    (addr, handle)
}

/// The acceptance scenario: remote plans/sweeps equal the in-process
/// planner bit for bit, concurrent clients are serviced, the second
/// identical sweep is served from the shared cache (stats verb shows
/// hits), and shutdown stops the daemon cleanly.
#[test]
fn remote_plans_are_byte_identical_and_cache_is_shared() {
    let (addr, handle) = boot(3);

    // Concurrent clients: two sweeps over the same small grid plus a
    // single-point plan, all in flight together.
    let combos = vec!["dqn_cartpole".to_string(), "a2c_invpend".to_string()];
    let batches = [36usize, 52];
    let sweep_a = {
        let (addr, combos) = (addr.clone(), combos.clone());
        std::thread::spawn(move || {
            RemotePlanner::connect(&addr).unwrap().sweep(&combos, &batches, true).unwrap()
        })
    };
    let sweep_b = {
        let (addr, combos) = (addr.clone(), combos.clone());
        std::thread::spawn(move || {
            RemotePlanner::connect(&addr).unwrap().sweep(&combos, &batches, true).unwrap()
        })
    };
    let solo = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            RemotePlanner::connect(&addr).unwrap().plan_named("ddpg_mntncar", 44, true).unwrap()
        })
    };
    let plans_a = sweep_a.join().unwrap();
    let plans_b = sweep_b.join().unwrap();
    let remote_solo = solo.join().unwrap();

    // Remote vs in-process: identical grids, identical optima.  (The
    // *value* of the optimum is unique, so makespan bits always agree;
    // full schedule byte-identity is asserted below on the
    // cache-mediated repeat sweep, where it is deterministic even if
    // the two concurrent first solves raced on a symmetric tie.)
    assert_eq!(plans_a.len(), combos.len() * batches.len());
    for (i, remote) in plans_a.iter().enumerate() {
        let c = combo(&combos[i / batches.len()]);
        let bs = batches[i % batches.len()];
        let local = static_phase(&c, bs, true);
        assert_eq!(remote.combo, c.name);
        assert_eq!(remote.batch, bs);
        assert_eq!(
            remote.makespan_us.to_bits(),
            local.schedule.makespan_us.to_bits(),
            "{} bs={bs}: remote and local makespans must be bit-identical",
            c.name
        );
        assert_eq!(remote.schedule.len(), local.schedule.entries.len());
        assert_eq!(remote.assignment.len(), local.solution.assignment.len());
    }
    // The two concurrent sweeps must agree on every optimum.
    for (a, b) in plans_a.iter().zip(&plans_b) {
        assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
    }
    let local_solo = static_phase(&combo("ddpg_mntncar"), 44, true);
    assert_eq!(
        remote_solo.makespan_us.to_bits(),
        local_solo.schedule.makespan_us.to_bits()
    );

    // Second identical sweep on a fresh connection: every point now
    // comes out of the shared cache, and the stats verb must say so.
    // These plans are byte-identical to the in-process planner's — same
    // cache entry, same deterministic schedule evaluation, schedule
    // times surviving the wire bit-for-bit.
    let client = RemotePlanner::connect(&addr).unwrap();
    let replans = client.sweep(&combos, &batches, true).unwrap();
    assert!(
        replans.iter().all(|p| p.cache_hit && p.explored == 0),
        "second identical sweep must be all cache hits"
    );
    for (i, remote) in replans.iter().enumerate() {
        let c = combo(&combos[i / batches.len()]);
        let bs = batches[i % batches.len()];
        let local = static_phase(&c, bs, true);
        assert!(local.cache_hit, "local control must read the same shared cache");
        for (r, l) in remote.schedule.iter().zip(&local.schedule.entries) {
            assert_eq!(r.node, l.node);
            assert_eq!(r.component, l.component.name());
            assert_eq!(r.start_us.to_bits(), l.start_us.to_bits());
            assert_eq!(r.finish_us.to_bits(), l.finish_us.to_bits());
        }
        for (r, l) in remote.assignment.iter().zip(&local.solution.assignment) {
            assert_eq!(r.0, l.component.name());
            assert_eq!(r.1, l.candidate);
        }
        assert_eq!(remote.step_time_us().to_bits(), local.step_time_us().to_bits());
    }
    let stats = client.stats().unwrap();
    let hits = stats
        .get("cache")
        .and_then(|c| c.get("hits"))
        .and_then(Json::as_usize)
        .expect("stats must carry cache.hits");
    assert!(hits > 0, "stats must report cache hits after the repeat sweep");
    let served = stats.get("plans_served").and_then(Json::as_usize).unwrap();
    assert!(served >= 3 * combos.len() * batches.len(), "all sweep points counted");

    // cache_flush empties the shared cache; the next sweep re-solves.
    let flushed = client.cache_flush().unwrap();
    assert!(flushed > 0, "flush must report evicted entries");

    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// Malformed requests and version mismatches get error responses on a
/// connection that stays usable; the protocol never kills the daemon.
#[test]
fn malformed_and_mismatched_requests_error_without_killing_the_connection() {
    let (addr, handle) = boot(2);
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut ask = |line: &str| -> Json {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        Json::parse(buf.trim()).expect("server must always answer valid JSON")
    };
    let err_of = |resp: &Json| -> String {
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        resp.get("error").and_then(Json::as_str).unwrap_or_default().to_string()
    };

    // Not JSON at all.
    let resp = ask("this is not json");
    assert!(err_of(&resp).contains("bad request"), "{resp}");
    // Valid JSON, wrong protocol version — rejected before the verb.
    let resp = ask(&format!(r#"{{"v":{},"verb":"stats"}}"#, PROTOCOL_VERSION + 40));
    assert!(err_of(&resp).contains("protocol version mismatch"), "{resp}");
    // Missing version field.
    let resp = ask(r#"{"verb":"stats"}"#);
    assert!(err_of(&resp).contains("missing protocol version"), "{resp}");
    // Unknown verb.
    let resp = ask(r#"{"v":3,"verb":"transmogrify"}"#);
    assert!(err_of(&resp).contains("unknown verb"), "{resp}");
    // Unknown combo: a *planning* error, still a clean protocol answer.
    let resp = ask(r#"{"v":3,"verb":"plan","combo":"dqn_tetris","batch":8}"#);
    assert!(err_of(&resp).contains("unknown combo"), "{resp}");
    // Zero batch.
    let resp = ask(r#"{"v":3,"verb":"plan","combo":"dqn_cartpole","batch":0}"#);
    assert!(err_of(&resp).contains("batch"), "{resp}");

    // After all those errors the same connection still serves requests.
    let resp = ask(r#"{"v":3,"verb":"stats"}"#);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
    let errors = resp
        .get("stats")
        .and_then(|s| s.get("errors"))
        .and_then(Json::as_usize)
        .unwrap();
    assert!(errors >= 6, "every bad request must be counted, got {errors}");

    // Tidy up the raw connection (both fd clones) before stopping the
    // daemon; per-request scheduling means it could not block shutdown,
    // but an explicit close keeps the teardown deterministic.
    drop(reader);
    drop(stream);
    RemotePlanner::connect(&addr).unwrap().shutdown().unwrap();
    handle.join().unwrap();
}

/// Regression for the sweep-duplication satellite: a `sweep` request
/// naming the same combo twice must NOT replan the repeated (combo,
/// batch) pairs — the handler dedupes by plan key, so every duplicate
/// point reports `explored == 0` (a memoized copy of the first), with a
/// bit-identical schedule.
#[test]
fn duplicate_combos_in_one_sweep_request_are_not_replanned() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let combos = vec![
        "ddpg_mntncar".to_string(),
        "ddpg_mntncar".to_string(),
        "dqn_cartpole".to_string(),
        "ddpg_mntncar".to_string(),
    ];
    let batches = [57usize];
    let plans = client.sweep(&combos, &batches, true).unwrap();
    assert_eq!(plans.len(), combos.len());
    for dup in [&plans[1], &plans[3]] {
        assert_eq!(dup.combo, "ddpg_mntncar");
        assert_eq!(
            dup.explored, 0,
            "repeated (combo, batch) point in one request must not re-search"
        );
        assert!(dup.cache_hit, "repeated point must be a memoized copy");
        assert_eq!(dup.makespan_us.to_bits(), plans[0].makespan_us.to_bits());
        for (a, b) in dup.schedule.iter().zip(&plans[0].schedule) {
            assert_eq!(a.start_us.to_bits(), b.start_us.to_bits());
            assert_eq!(a.finish_us.to_bits(), b.finish_us.to_bits());
        }
        assert_eq!(dup.assignment, plans[0].assignment);
    }
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// The protocol-v2 streaming sweep over a raw socket: `"stream":true`
/// pushes one `progress` line per grid point (each a well-formed ok
/// response), then the usual `plans[]` line last — and a legacy-style
/// request without the flag still gets exactly one response line.
#[test]
fn streaming_sweep_pushes_progress_lines_then_the_final_plans() {
    let (addr, handle) = boot(2);
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let line = concat!(
        r#"{"v":3,"verb":"sweep","combos":["dqn_cartpole","a2c_invpend"],"#,
        r#""batches":[41],"quantized":true,"stream":true}"#
    );
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut progress = Vec::new();
    let final_resp = loop {
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        let resp = Json::parse(buf.trim()).expect("every pushed line must be valid JSON");
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
        match resp.get("progress") {
            Some(p) => progress.push(p.clone()),
            None => break resp,
        }
    };
    assert_eq!(progress.len(), 2, "one progress line per grid point");
    for p in &progress {
        assert_eq!(p.get("total").and_then(Json::as_usize), Some(2));
        assert!(p.get("combo").and_then(Json::as_str).is_some());
        assert!(p.get("done").and_then(Json::as_usize).is_some());
        assert!(p.get("solve_us").is_some());
    }
    assert!(
        progress.iter().any(|p| p.get("done").and_then(Json::as_usize) == Some(2)),
        "the last progress line must report the full count"
    );
    let plans = final_resp.get("plans").and_then(Json::as_arr).unwrap();
    assert_eq!(plans.len(), 2, "final line carries the whole grid");
    drop(reader);
    drop(stream);
    RemotePlanner::connect(&addr).unwrap().shutdown().unwrap();
    handle.join().unwrap();
}

/// Client-level streaming: `sweep_stream` fires the progress callback
/// once per grid point and returns plans bit-identical to the plain
/// (non-streaming) sweep of the same grid.
#[test]
fn sweep_stream_reports_every_point_and_matches_the_plain_sweep() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let combos = vec!["ddpg_mntncar".to_string(), "dqn_cartpole".to_string()];
    let batches = [45usize, 61];
    let mut seen = Vec::new();
    let streamed = client
        .sweep_stream(&combos, &batches, true, &mut |p| {
            seen.push((
                p.get("combo").and_then(Json::as_str).unwrap_or("?").to_string(),
                p.get("done").and_then(Json::as_usize).unwrap_or(0),
            ));
        })
        .unwrap();
    assert_eq!(seen.len(), combos.len() * batches.len(), "one callback per point");
    assert_eq!(seen.iter().map(|(_, d)| *d).max(), Some(seen.len()));
    let plain = client.sweep(&combos, &batches, true).unwrap();
    assert_eq!(streamed.len(), plain.len());
    for (s, p) in streamed.iter().zip(&plain) {
        assert_eq!(s.combo, p.combo);
        assert_eq!(s.batch, p.batch);
        assert_eq!(s.makespan_us.to_bits(), p.makespan_us.to_bits());
    }
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// The `profile` verb exposes the DSE candidate tables over the wire:
/// per node, the PS latency and every PL/AIE (format, latency, resource)
/// candidate the ILP chooses from.
#[test]
fn profile_verb_serves_the_dse_candidate_table() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let payload = client.profile("dqn_cartpole", 32, true).unwrap();
    assert_eq!(payload.get("combo").and_then(Json::as_str), Some("dqn_cartpole"));
    assert_eq!(payload.get("batch").and_then(Json::as_usize), Some(32));
    let nodes = payload.get("nodes").and_then(Json::as_arr).expect("nodes array");
    assert!(!nodes.is_empty(), "a real graph has nodes");
    for n in nodes {
        assert!(n.get("name").and_then(Json::as_str).is_some());
        assert!(n.get("ps_latency_us").and_then(Json::as_f64).is_some());
        let pl = n.get("pl").and_then(Json::as_arr).expect("pl candidates");
        assert!(!pl.is_empty(), "every node has at least one PL candidate");
        for cand in pl {
            assert!(cand.get("fmt").and_then(Json::as_str).is_some());
            assert!(cand.get("latency_us").and_then(Json::as_f64).unwrap() > 0.0);
        }
    }
    // Unknown combos are a clean protocol error, not a dead daemon.
    assert!(client.profile("dqn_tetris", 32, true).is_err());
    let stats = client.stats().unwrap();
    assert!(
        stats.get("latency_us").and_then(|l| l.get("profile")).is_some(),
        "per-verb latency must cover the profile verb: {stats}"
    );
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// FP32 vs quantized travel the wire as distinct plans, and the remote
/// side sees the same precision-dependent formats the local one does.
#[test]
fn remote_respects_precision_mode() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let quant = client.plan_named("ddpg_lunar", 96, true).unwrap();
    let fp32 = client.plan_named("ddpg_lunar", 96, false).unwrap();
    assert!(quant.quantized && !fp32.quantized);
    assert!(
        fp32.schedule.iter().all(|e| e.format == "FP32"),
        "FP32 control must not carry reduced-precision formats"
    );
    let local_q = static_phase(&combo("ddpg_lunar"), 96, true);
    assert_eq!(quant.makespan_us.to_bits(), local_q.schedule.makespan_us.to_bits());
    let local_f = static_phase(&combo("ddpg_lunar"), 96, false);
    assert_eq!(fp32.makespan_us.to_bits(), local_f.schedule.makespan_us.to_bits());
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// The protocol-v3 `train` verb end to end: the daemon schedules the
/// job, streams episode/checkpoint/progress frames all tagged with the
/// job id, and the final payload's metrics are **bit-identical** to
/// the same run trained in-process — every streamed episode reward is
/// the reward log entry itself, not an approximation of it.
#[test]
fn train_verb_streams_frames_and_returns_bit_exact_metrics() {
    let (addr, handle) = boot(2);
    let trainer = RemoteTrainer::connect(&[addr.clone()]).unwrap();
    let sub = TrainSubmission {
        combo: "dqn_cartpole".into(),
        seed: 1,
        actors: 1,
        max_env_steps: 400,
        max_episodes: 10_000,
        quantized: false,
        priority: 0,
        checkpoint_every: 150,
        progress_every: 100,
    };
    let mut frames = Vec::new();
    let result = trainer.train(&sub, &mut |_, f| frames.push(f.clone())).unwrap();
    assert_eq!(result.get("status").and_then(Json::as_str), Some("done"), "{result}");
    assert_eq!(result.get("cancelled").and_then(Json::as_bool), Some(false));
    let job = result.get("job").and_then(Json::as_str).unwrap().to_string();
    let kinds: Vec<&str> =
        frames.iter().filter_map(|f| f.get("frame").and_then(Json::as_str)).collect();
    for want in ["episode", "checkpoint", "progress"] {
        assert!(kinds.contains(&want), "missing {want} frame in {kinds:?}");
    }
    assert!(
        frames.iter().all(|f| f.get("job").and_then(Json::as_str) == Some(job.as_str())),
        "every streamed frame must carry its job id"
    );
    let metrics = RunMetrics::from_json(result.get("metrics").expect("metrics")).unwrap();
    for f in &frames {
        if f.get("frame").and_then(Json::as_str) != Some("episode") {
            continue;
        }
        let n = f.get("episode").and_then(Json::as_usize).unwrap();
        let r = f.get("reward").and_then(Json::as_f64).unwrap();
        assert_eq!(r.to_bits(), metrics.episode_rewards[n - 1].to_bits());
    }
    // In-process control over the identical plan path: the remote job
    // must reproduce the local trajectory bit for bit.
    let c = combo("dqn_cartpole");
    let plan = LocalPlanner.plan(&PlanRequest::new(c.clone(), c.batch, false)).unwrap();
    let mut backend = CpuBackend::from_outcome(&plan).unwrap();
    let limits = TrainLimits { max_env_steps: 400, max_episodes: 10_000 };
    let local = train_combo_actors(&mut backend, &c, 1, limits, 1, false).unwrap();
    assert_eq!(local.metrics.episode_rewards, metrics.episode_rewards);
    assert_eq!(local.metrics.losses, metrics.losses);
    assert_eq!(local.metrics.train_steps, metrics.train_steps);
    assert_eq!(local.metrics.env_steps, metrics.env_steps);
    // Job telemetry made it into the stats verb.
    let client = RemotePlanner::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    let jobs = stats.get("jobs").expect("stats must carry a jobs section");
    assert_eq!(jobs.get("completed").and_then(Json::as_usize), Some(1), "{stats}");
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// `jobs` and `cancel` manage the scheduler over the wire: a running
/// job shows up in the listing, a cancel stops it at the next round
/// boundary with its prefix metrics intact, and cancelling an unknown
/// id is a clean protocol error — not a dead daemon.
#[test]
fn jobs_listing_and_cancel_stop_a_running_job() {
    let (addr, handle) = boot(2);
    let addr2 = addr.clone();
    let worker = std::thread::spawn(move || {
        let trainer = RemoteTrainer::connect(&[addr2]).unwrap();
        let sub = TrainSubmission {
            combo: "dqn_cartpole".into(),
            seed: 3,
            actors: 1,
            max_env_steps: 50_000_000, // far beyond any test budget: only cancel ends it
            max_episodes: 10_000_000,
            quantized: false,
            priority: 0,
            checkpoint_every: 1_000,
            progress_every: 0,
        };
        trainer.train(&sub, &mut |_, _| {}).unwrap()
    });
    let client = RemotePlanner::connect(&addr).unwrap();
    let mut tries = 0;
    let job = loop {
        tries += 1;
        assert!(tries < 2_000, "job never reached the runner");
        let (jobs, draining) = client.jobs().unwrap();
        assert!(!draining);
        let running = jobs
            .as_arr()
            .unwrap()
            .iter()
            .find(|j| j.get("phase").and_then(Json::as_str) == Some("running"))
            .and_then(|j| j.get("job").and_then(Json::as_str))
            .map(str::to_string);
        if let Some(id) = running {
            break id;
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(client.cancel_job(&job).unwrap(), "running");
    let result = worker.join().unwrap();
    assert_eq!(result.get("status").and_then(Json::as_str), Some("cancelled"), "{result}");
    assert_eq!(result.get("cancelled").and_then(Json::as_bool), Some(true));
    assert!(result.get("metrics").is_some(), "prefix metrics must be reported: {result}");
    assert!(client.cancel_job("job-404").is_err());
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// Graceful shutdown drains running jobs: the streaming client gets
/// exactly one final hand-off checkpoint frame, then a cancelled
/// result flagged `draining` — which, with no survivor to resubmit to,
/// the trainer surfaces as an every-host-is-draining error.
#[test]
fn shutdown_drains_a_running_job_to_a_handoff_checkpoint() {
    let (addr, handle) = boot(2);
    let addr2 = addr.clone();
    let worker = std::thread::spawn(move || {
        let trainer = RemoteTrainer::connect(&[addr2]).unwrap();
        let sub = TrainSubmission {
            combo: "dqn_cartpole".into(),
            seed: 5,
            actors: 1,
            max_env_steps: 50_000_000, // runs until the drain cancels it
            max_episodes: 10_000_000,
            quantized: false,
            priority: 0,
            checkpoint_every: 200,
            progress_every: 0,
        };
        let mut finals = 0usize;
        let err = trainer
            .train(&sub, &mut |_, f| {
                if f.get("frame").and_then(Json::as_str) == Some("checkpoint")
                    && f.get("final").and_then(Json::as_bool) == Some(true)
                {
                    finals += 1;
                }
            })
            .unwrap_err();
        (finals, format!("{err:#}"))
    });
    let client = RemotePlanner::connect(&addr).unwrap();
    let mut tries = 0;
    loop {
        tries += 1;
        assert!(tries < 2_000, "job never reached the runner");
        let (jobs, _) = client.jobs().unwrap();
        let running = jobs
            .as_arr()
            .unwrap()
            .iter()
            .any(|j| j.get("phase").and_then(Json::as_str) == Some("running"));
        if running {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    client.shutdown().unwrap();
    let (finals, err) = worker.join().unwrap();
    assert_eq!(finals, 1, "a drained job must stream exactly one final hand-off checkpoint");
    assert!(err.contains("draining"), "{err}");
    handle.join().unwrap();
}

/// The acceptance fail-over scenario: two daemons, a mid-job drain of
/// the serving host, and the client resubmitting its newest streamed
/// checkpoint to the survivor.  The job completes on the second host
/// and the full streamed episode log — the dying host's prefix plus
/// the survivor's replayed remainder — matches the final reward log
/// bit for bit.
#[test]
fn dying_host_hands_the_job_off_to_a_survivor_bit_exactly() {
    let (addr_a, handle_a) = boot(2);
    let (addr_b, handle_b) = boot(2);
    let trainer = RemoteTrainer::connect(&[addr_a.clone(), addr_b.clone()]).unwrap();
    let sub = TrainSubmission {
        combo: "dqn_cartpole".into(),
        seed: 2,
        actors: 1,
        max_env_steps: 6_000,
        max_episodes: 10_000,
        quantized: false,
        priority: 0,
        checkpoint_every: 100,
        progress_every: 0,
    };
    let mut episodes: Vec<(usize, f64)> = Vec::new();
    let mut hosts_seen: Vec<String> = Vec::new();
    let mut killed: Option<String> = None;
    let result = trainer
        .train(&sub, &mut |host, f| {
            if !hosts_seen.contains(&host.to_string()) {
                hosts_seen.push(host.to_string());
            }
            match f.get("frame").and_then(Json::as_str) {
                Some("episode") => episodes.push((
                    f.get("episode").and_then(Json::as_usize).unwrap(),
                    f.get("reward").and_then(Json::as_f64).unwrap(),
                )),
                // First checkpoint: take down the serving host mid-job,
                // forcing the hand-off path.
                Some("checkpoint") if killed.is_none() => {
                    killed = Some(host.to_string());
                    RemotePlanner::connect(host).unwrap().shutdown().unwrap();
                }
                _ => {}
            }
        })
        .unwrap();
    assert_eq!(result.get("status").and_then(Json::as_str), Some("done"), "{result}");
    let killed = killed.expect("a checkpoint frame must have arrived");
    assert_eq!(hosts_seen.len(), 2, "the job must stream from both hosts: {hosts_seen:?}");
    let metrics = RunMetrics::from_json(result.get("metrics").expect("metrics")).unwrap();
    assert!(metrics.env_steps >= 6_000, "the resumed job must run to its step limit");
    assert!(!episodes.is_empty());
    for (n, r) in &episodes {
        assert_eq!(
            r.to_bits(),
            metrics.episode_rewards[n - 1].to_bits(),
            "streamed episode {n} diverged from the final reward log"
        );
    }
    let survivor = if killed == addr_a { &addr_b } else { &addr_a };
    RemotePlanner::connect(survivor).unwrap().shutdown().unwrap();
    handle_a.join().unwrap();
    handle_b.join().unwrap();
}

/// One raw request/response round trip over a fresh connection.
fn raw_request(addr: &str, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    reader.read_line(&mut buf).unwrap();
    Json::parse(buf.trim()).expect("server must answer valid JSON")
}

/// The acceptance crash-recovery scenario: a daemon with `APDRL_JOB_DIR`
/// set is SIGKILLed mid-job (right after its first spilled checkpoint),
/// restarted on the same journal directory, and the recovered job runs
/// to completion headless — with a final reward log **bit-identical**
/// to an uninterrupted in-process control run of the same spec.  Runs
/// the real binary: recovery must survive a hard process death, not a
/// graceful drain.
#[test]
fn sigkilled_daemon_resumes_jobs_bit_identically_after_restart() {
    let exe = env!("CARGO_BIN_EXE_apdrl");
    let dir = std::env::temp_dir()
        .join(format!("apdrl_restart_test_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // Reserve an ephemeral port, then free it for the child to bind.
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        addr
    };
    let spawn = |dir: &std::path::Path| {
        std::process::Command::new(exe)
            .args(["serve", "--addr", &addr, "--workers", "2"])
            .env("APDRL_JOB_DIR", dir)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawning apdrl serve must work")
    };
    let wait_ready = |addr: &str| {
        for _ in 0..100 {
            if TcpStream::connect(addr).is_ok() {
                return;
            }
            std::thread::sleep(Duration::from_millis(100));
        }
        panic!("daemon at {addr} never came up");
    };

    let mut child = spawn(&dir);
    wait_ready(&addr);

    // Submit the job *detached* (no client to fail over — the daemon
    // restart must do the resuming), watch the journal *file* for the
    // first spilled checkpoint, and hard-kill the daemon.  No TCP
    // connection is open at kill time: a SIGKILLed peer of a live
    // stream would leave the port in TIME_WAIT and the rebind flaky.
    let ack = raw_request(
        &addr,
        r#"{"v":3,"verb":"train","combo":"dqn_cartpole","seed":9,"max_env_steps":12000,"max_episodes":100000,"checkpoint_every":150,"detach":true}"#,
    );
    assert_eq!(ack.get("job").and_then(Json::as_str), Some("job-0"), "{ack}");
    let mut tries = 0;
    loop {
        tries += 1;
        assert!(tries < 3_000, "no checkpoint ever spilled to the journal");
        let spilled = Journal::open(&dir)
            .load_all()
            .iter()
            .any(|r| r.id == "job-0" && r.phase == "running" && r.spec.resume.is_some());
        if spilled {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    child.kill().unwrap(); // SIGKILL: no drain, no final checkpoint
    child.wait().unwrap();

    // Restart on the same journal directory: the job must come back as
    // a recovered entry and run to completion without any client.
    let mut child = spawn(&dir);
    wait_ready(&addr);
    let client = RemotePlanner::connect(&addr).unwrap();
    let mut tries = 0;
    let recovered_entry = loop {
        tries += 1;
        assert!(tries < 3_000, "recovered job never completed");
        let (jobs, _) = client.jobs().unwrap();
        let done = jobs.as_arr().unwrap().iter().find(|j| {
            j.get("job").and_then(Json::as_str) == Some("job-0")
                && j.get("phase").and_then(Json::as_str) == Some("done")
        });
        if let Some(j) = done {
            break j.clone();
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(
        recovered_entry.get("recovered").and_then(Json::as_bool),
        Some(true),
        "the listing must report journal-replay provenance: {recovered_entry}"
    );
    let stats = client.stats().unwrap();
    assert_eq!(
        stats.get("jobs").and_then(|j| j.get("recovered")).and_then(Json::as_usize),
        Some(1),
        "{stats}"
    );

    // The journal's terminal record holds the final checkpoint; its
    // reward log must match an uninterrupted control bit for bit.
    let records = Journal::open(&dir).load_all();
    let rec = records.iter().find(|r| r.id == "job-0").expect("journal record for job-0");
    assert_eq!(rec.phase, "done");
    let ckpt = rec.spec.resume.as_ref().expect("terminal record keeps the final checkpoint");
    let c = combo("dqn_cartpole");
    let plan = LocalPlanner.plan(&PlanRequest::new(c.clone(), c.batch, false)).unwrap();
    let mut backend = CpuBackend::from_outcome(&plan).unwrap();
    let limits = TrainLimits { max_env_steps: 12_000, max_episodes: 100_000 };
    let control = train_combo_actors(&mut backend, &c, 9, limits, 1, false).unwrap();
    assert_eq!(
        hex_f64s(&ckpt.metrics.episode_rewards),
        hex_f64s(&control.metrics.episode_rewards),
        "SIGKILLed-and-restarted run diverged from the uninterrupted control"
    );
    assert_eq!(ckpt.metrics.env_steps, control.metrics.env_steps);
    assert_eq!(ckpt.metrics.train_steps, control.metrics.train_steps);

    client.shutdown().unwrap();
    child.wait().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The queue-gossip acceptance scenario: host A runs two jobs (one
/// streamed, one detached filler) with a third job queued behind them;
/// checkpoint frames gossip A's queued digest to the streaming client;
/// when A drains, the client fails the queued job over to survivor B —
/// exactly once, origin-tagged — while the streamed job itself resumes
/// on B from its newest checkpoint.
#[test]
fn dead_hosts_queued_jobs_fail_over_to_survivors_exactly_once() {
    let (addr_a, handle_a) = boot(2);
    let (addr_b, handle_b) = boot(2);

    // The streamed job: long enough to outlive the choreography, short
    // enough to finish on B.  Hosts are tried in order on a load tie,
    // so the first submission lands on A.
    let sub = TrainSubmission {
        combo: "dqn_cartpole".into(),
        seed: 4,
        actors: 1,
        max_env_steps: 8_000,
        max_episodes: 100_000,
        quantized: false,
        priority: 0,
        checkpoint_every: 100,
        progress_every: 0,
    };
    let (addr_a2, addr_b2) = (addr_a.clone(), addr_b.clone());
    let worker = std::thread::spawn(move || {
        let trainer = RemoteTrainer::connect(&[addr_a2.clone(), addr_b2]).unwrap();
        let mut killed = false;
        let result = trainer
            .train(&sub, &mut |host, f| {
                if killed || f.get("frame").and_then(Json::as_str) != Some("checkpoint") {
                    return;
                }
                // Shut A down only once its gossiped digest shows the
                // queued fail-over candidate.
                let queued_has_candidate = f
                    .get("queued")
                    .and_then(Json::as_arr)
                    .map(|entries| {
                        entries.iter().any(|e| {
                            e.get("combo").and_then(Json::as_str) == Some("a2c_invpend")
                        })
                    })
                    .unwrap_or(false);
                if queued_has_candidate && host == addr_a2 {
                    killed = true;
                    RemotePlanner::connect(host).unwrap().shutdown().unwrap();
                }
            })
            .unwrap();
        (result, killed)
    });

    // Wait for the streamed job to occupy A's first runner…
    let client_a = RemotePlanner::connect(&addr_a).unwrap();
    let wait_running = |client: &RemotePlanner, id: &str| {
        let mut tries = 0;
        loop {
            tries += 1;
            assert!(tries < 2_000, "{id} never reached a runner");
            let (jobs, _) = client.jobs().unwrap();
            let running = jobs.as_arr().unwrap().iter().any(|j| {
                j.get("job").and_then(Json::as_str) == Some(id)
                    && j.get("phase").and_then(Json::as_str) == Some("running")
            });
            if running {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    };
    wait_running(&client_a, "job-0");
    // …fill the second runner with an endless detached job…
    let filler = raw_request(
        &addr_a,
        r#"{"v":3,"verb":"train","combo":"dqn_cartpole","seed":6,"max_env_steps":50000000,"max_episodes":10000000,"detach":true}"#,
    );
    assert_eq!(filler.get("detached").and_then(Json::as_bool), Some(true), "{filler}");
    wait_running(&client_a, "job-1");
    // …and queue the fail-over candidate behind both.
    let queued = raw_request(
        &addr_a,
        r#"{"v":3,"verb":"train","combo":"a2c_invpend","seed":8,"max_env_steps":400,"max_episodes":100000,"detach":true}"#,
    );
    assert_eq!(queued.get("job").and_then(Json::as_str), Some("job-2"), "{queued}");

    // The worker sees the digest, drains A, fails the queue over to B,
    // and finishes the streamed job there.
    let (result, killed) = worker.join().unwrap();
    assert!(killed, "the streaming client never saw job-2 in A's gossiped digest");
    assert_eq!(result.get("status").and_then(Json::as_str), Some("done"), "{result}");
    let metrics = RunMetrics::from_json(result.get("metrics").expect("metrics")).unwrap();
    assert!(metrics.env_steps >= 8_000, "the resumed job must run to its step limit");

    // Survivor B must complete the failed-over job exactly once,
    // origin-tagged back to A's job id.
    let client_b = RemotePlanner::connect(&addr_b).unwrap();
    let mut tries = 0;
    let origin_jobs: Vec<Json> = loop {
        tries += 1;
        assert!(tries < 2_000, "failed-over job never completed on the survivor");
        let (jobs, _) = client_b.jobs().unwrap();
        let tagged: Vec<Json> = jobs
            .as_arr()
            .unwrap()
            .iter()
            .filter(|j| j.get("origin").is_some())
            .cloned()
            .collect();
        let all_done = !tagged.is_empty()
            && tagged
                .iter()
                .all(|j| j.get("phase").and_then(Json::as_str) == Some("done"));
        if all_done {
            break tagged;
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(origin_jobs.len(), 1, "exactly one fail-over copy: {origin_jobs:?}");
    let rescued = &origin_jobs[0];
    assert_eq!(rescued.get("combo").and_then(Json::as_str), Some("a2c_invpend"));
    assert_eq!(
        rescued.get("origin").and_then(Json::as_str),
        Some(format!("{addr_a}/job-2").as_str()),
        "the origin tag must name the dead host's job id"
    );
    assert_eq!(rescued.get("seed").and_then(Json::as_f64), Some(8.0));

    client_b.shutdown().unwrap();
    handle_a.join().unwrap();
    handle_b.join().unwrap();
}
