//! Integration tests for the planning server: boot a daemon on an
//! ephemeral port, drive it with concurrent `plan`/`sweep` clients,
//! assert remote schedules are *byte-identical* to the in-process
//! planner's, and exercise the malformed-request and protocol-version
//! error paths.  Everything runs on the default (non-`pjrt`) feature
//! set over loopback TCP.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use apdrl::coordinator::{combo, static_phase};
use apdrl::server::{RemotePlanner, Server, PROTOCOL_VERSION};
use apdrl::util::json::Json;

/// Boot a server on an ephemeral loopback port; returns its address and
/// the thread that runs it (joined after `shutdown`).
fn boot(workers: usize) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", workers).expect("ephemeral bind must work");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run().expect("server run must not error"));
    (addr, handle)
}

/// The acceptance scenario: remote plans/sweeps equal the in-process
/// planner bit for bit, concurrent clients are serviced, the second
/// identical sweep is served from the shared cache (stats verb shows
/// hits), and shutdown stops the daemon cleanly.
#[test]
fn remote_plans_are_byte_identical_and_cache_is_shared() {
    let (addr, handle) = boot(3);

    // Concurrent clients: two sweeps over the same small grid plus a
    // single-point plan, all in flight together.
    let combos = vec!["dqn_cartpole".to_string(), "a2c_invpend".to_string()];
    let batches = [36usize, 52];
    let sweep_a = {
        let (addr, combos) = (addr.clone(), combos.clone());
        std::thread::spawn(move || {
            RemotePlanner::connect(&addr).unwrap().sweep(&combos, &batches, true).unwrap()
        })
    };
    let sweep_b = {
        let (addr, combos) = (addr.clone(), combos.clone());
        std::thread::spawn(move || {
            RemotePlanner::connect(&addr).unwrap().sweep(&combos, &batches, true).unwrap()
        })
    };
    let solo = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            RemotePlanner::connect(&addr).unwrap().plan_named("ddpg_mntncar", 44, true).unwrap()
        })
    };
    let plans_a = sweep_a.join().unwrap();
    let plans_b = sweep_b.join().unwrap();
    let remote_solo = solo.join().unwrap();

    // Remote vs in-process: identical grids, identical optima.  (The
    // *value* of the optimum is unique, so makespan bits always agree;
    // full schedule byte-identity is asserted below on the
    // cache-mediated repeat sweep, where it is deterministic even if
    // the two concurrent first solves raced on a symmetric tie.)
    assert_eq!(plans_a.len(), combos.len() * batches.len());
    for (i, remote) in plans_a.iter().enumerate() {
        let c = combo(&combos[i / batches.len()]);
        let bs = batches[i % batches.len()];
        let local = static_phase(&c, bs, true);
        assert_eq!(remote.combo, c.name);
        assert_eq!(remote.batch, bs);
        assert_eq!(
            remote.makespan_us.to_bits(),
            local.schedule.makespan_us.to_bits(),
            "{} bs={bs}: remote and local makespans must be bit-identical",
            c.name
        );
        assert_eq!(remote.schedule.len(), local.schedule.entries.len());
        assert_eq!(remote.assignment.len(), local.solution.assignment.len());
    }
    // The two concurrent sweeps must agree on every optimum.
    for (a, b) in plans_a.iter().zip(&plans_b) {
        assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
    }
    let local_solo = static_phase(&combo("ddpg_mntncar"), 44, true);
    assert_eq!(
        remote_solo.makespan_us.to_bits(),
        local_solo.schedule.makespan_us.to_bits()
    );

    // Second identical sweep on a fresh connection: every point now
    // comes out of the shared cache, and the stats verb must say so.
    // These plans are byte-identical to the in-process planner's — same
    // cache entry, same deterministic schedule evaluation, schedule
    // times surviving the wire bit-for-bit.
    let client = RemotePlanner::connect(&addr).unwrap();
    let replans = client.sweep(&combos, &batches, true).unwrap();
    assert!(
        replans.iter().all(|p| p.cache_hit && p.explored == 0),
        "second identical sweep must be all cache hits"
    );
    for (i, remote) in replans.iter().enumerate() {
        let c = combo(&combos[i / batches.len()]);
        let bs = batches[i % batches.len()];
        let local = static_phase(&c, bs, true);
        assert!(local.cache_hit, "local control must read the same shared cache");
        for (r, l) in remote.schedule.iter().zip(&local.schedule.entries) {
            assert_eq!(r.node, l.node);
            assert_eq!(r.component, l.component.name());
            assert_eq!(r.start_us.to_bits(), l.start_us.to_bits());
            assert_eq!(r.finish_us.to_bits(), l.finish_us.to_bits());
        }
        for (r, l) in remote.assignment.iter().zip(&local.solution.assignment) {
            assert_eq!(r.0, l.component.name());
            assert_eq!(r.1, l.candidate);
        }
        assert_eq!(remote.step_time_us().to_bits(), local.step_time_us().to_bits());
    }
    let stats = client.stats().unwrap();
    let hits = stats
        .get("cache")
        .and_then(|c| c.get("hits"))
        .and_then(Json::as_usize)
        .expect("stats must carry cache.hits");
    assert!(hits > 0, "stats must report cache hits after the repeat sweep");
    let served = stats.get("plans_served").and_then(Json::as_usize).unwrap();
    assert!(served >= 3 * combos.len() * batches.len(), "all sweep points counted");

    // cache_flush empties the shared cache; the next sweep re-solves.
    let flushed = client.cache_flush().unwrap();
    assert!(flushed > 0, "flush must report evicted entries");

    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// Malformed requests and version mismatches get error responses on a
/// connection that stays usable; the protocol never kills the daemon.
#[test]
fn malformed_and_mismatched_requests_error_without_killing_the_connection() {
    let (addr, handle) = boot(2);
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut ask = |line: &str| -> Json {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        Json::parse(buf.trim()).expect("server must always answer valid JSON")
    };
    let err_of = |resp: &Json| -> String {
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        resp.get("error").and_then(Json::as_str).unwrap_or_default().to_string()
    };

    // Not JSON at all.
    let resp = ask("this is not json");
    assert!(err_of(&resp).contains("bad request"), "{resp}");
    // Valid JSON, wrong protocol version — rejected before the verb.
    let resp = ask(&format!(r#"{{"v":{},"verb":"stats"}}"#, PROTOCOL_VERSION + 40));
    assert!(err_of(&resp).contains("protocol version mismatch"), "{resp}");
    // Missing version field.
    let resp = ask(r#"{"verb":"stats"}"#);
    assert!(err_of(&resp).contains("missing protocol version"), "{resp}");
    // Unknown verb.
    let resp = ask(r#"{"v":2,"verb":"transmogrify"}"#);
    assert!(err_of(&resp).contains("unknown verb"), "{resp}");
    // Unknown combo: a *planning* error, still a clean protocol answer.
    let resp = ask(r#"{"v":2,"verb":"plan","combo":"dqn_tetris","batch":8}"#);
    assert!(err_of(&resp).contains("unknown combo"), "{resp}");
    // Zero batch.
    let resp = ask(r#"{"v":2,"verb":"plan","combo":"dqn_cartpole","batch":0}"#);
    assert!(err_of(&resp).contains("batch"), "{resp}");

    // After all those errors the same connection still serves requests.
    let resp = ask(r#"{"v":2,"verb":"stats"}"#);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
    let errors = resp
        .get("stats")
        .and_then(|s| s.get("errors"))
        .and_then(Json::as_usize)
        .unwrap();
    assert!(errors >= 6, "every bad request must be counted, got {errors}");

    // Tidy up the raw connection (both fd clones) before stopping the
    // daemon; per-request scheduling means it could not block shutdown,
    // but an explicit close keeps the teardown deterministic.
    drop(reader);
    drop(stream);
    RemotePlanner::connect(&addr).unwrap().shutdown().unwrap();
    handle.join().unwrap();
}

/// Regression for the sweep-duplication satellite: a `sweep` request
/// naming the same combo twice must NOT replan the repeated (combo,
/// batch) pairs — the handler dedupes by plan key, so every duplicate
/// point reports `explored == 0` (a memoized copy of the first), with a
/// bit-identical schedule.
#[test]
fn duplicate_combos_in_one_sweep_request_are_not_replanned() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let combos = vec![
        "ddpg_mntncar".to_string(),
        "ddpg_mntncar".to_string(),
        "dqn_cartpole".to_string(),
        "ddpg_mntncar".to_string(),
    ];
    let batches = [57usize];
    let plans = client.sweep(&combos, &batches, true).unwrap();
    assert_eq!(plans.len(), combos.len());
    for dup in [&plans[1], &plans[3]] {
        assert_eq!(dup.combo, "ddpg_mntncar");
        assert_eq!(
            dup.explored, 0,
            "repeated (combo, batch) point in one request must not re-search"
        );
        assert!(dup.cache_hit, "repeated point must be a memoized copy");
        assert_eq!(dup.makespan_us.to_bits(), plans[0].makespan_us.to_bits());
        for (a, b) in dup.schedule.iter().zip(&plans[0].schedule) {
            assert_eq!(a.start_us.to_bits(), b.start_us.to_bits());
            assert_eq!(a.finish_us.to_bits(), b.finish_us.to_bits());
        }
        assert_eq!(dup.assignment, plans[0].assignment);
    }
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// The protocol-v2 streaming sweep over a raw socket: `"stream":true`
/// pushes one `progress` line per grid point (each a well-formed ok
/// response), then the usual `plans[]` line last — and a legacy-style
/// request without the flag still gets exactly one response line.
#[test]
fn streaming_sweep_pushes_progress_lines_then_the_final_plans() {
    let (addr, handle) = boot(2);
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let line = concat!(
        r#"{"v":2,"verb":"sweep","combos":["dqn_cartpole","a2c_invpend"],"#,
        r#""batches":[41],"quantized":true,"stream":true}"#
    );
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut progress = Vec::new();
    let final_resp = loop {
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        let resp = Json::parse(buf.trim()).expect("every pushed line must be valid JSON");
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
        match resp.get("progress") {
            Some(p) => progress.push(p.clone()),
            None => break resp,
        }
    };
    assert_eq!(progress.len(), 2, "one progress line per grid point");
    for p in &progress {
        assert_eq!(p.get("total").and_then(Json::as_usize), Some(2));
        assert!(p.get("combo").and_then(Json::as_str).is_some());
        assert!(p.get("done").and_then(Json::as_usize).is_some());
        assert!(p.get("solve_us").is_some());
    }
    assert!(
        progress.iter().any(|p| p.get("done").and_then(Json::as_usize) == Some(2)),
        "the last progress line must report the full count"
    );
    let plans = final_resp.get("plans").and_then(Json::as_arr).unwrap();
    assert_eq!(plans.len(), 2, "final line carries the whole grid");
    drop(reader);
    drop(stream);
    RemotePlanner::connect(&addr).unwrap().shutdown().unwrap();
    handle.join().unwrap();
}

/// Client-level streaming: `sweep_stream` fires the progress callback
/// once per grid point and returns plans bit-identical to the plain
/// (non-streaming) sweep of the same grid.
#[test]
fn sweep_stream_reports_every_point_and_matches_the_plain_sweep() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let combos = vec!["ddpg_mntncar".to_string(), "dqn_cartpole".to_string()];
    let batches = [45usize, 61];
    let mut seen = Vec::new();
    let streamed = client
        .sweep_stream(&combos, &batches, true, &mut |p| {
            seen.push((
                p.get("combo").and_then(Json::as_str).unwrap_or("?").to_string(),
                p.get("done").and_then(Json::as_usize).unwrap_or(0),
            ));
        })
        .unwrap();
    assert_eq!(seen.len(), combos.len() * batches.len(), "one callback per point");
    assert_eq!(seen.iter().map(|(_, d)| *d).max(), Some(seen.len()));
    let plain = client.sweep(&combos, &batches, true).unwrap();
    assert_eq!(streamed.len(), plain.len());
    for (s, p) in streamed.iter().zip(&plain) {
        assert_eq!(s.combo, p.combo);
        assert_eq!(s.batch, p.batch);
        assert_eq!(s.makespan_us.to_bits(), p.makespan_us.to_bits());
    }
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// The `profile` verb exposes the DSE candidate tables over the wire:
/// per node, the PS latency and every PL/AIE (format, latency, resource)
/// candidate the ILP chooses from.
#[test]
fn profile_verb_serves_the_dse_candidate_table() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let payload = client.profile("dqn_cartpole", 32, true).unwrap();
    assert_eq!(payload.get("combo").and_then(Json::as_str), Some("dqn_cartpole"));
    assert_eq!(payload.get("batch").and_then(Json::as_usize), Some(32));
    let nodes = payload.get("nodes").and_then(Json::as_arr).expect("nodes array");
    assert!(!nodes.is_empty(), "a real graph has nodes");
    for n in nodes {
        assert!(n.get("name").and_then(Json::as_str).is_some());
        assert!(n.get("ps_latency_us").and_then(Json::as_f64).is_some());
        let pl = n.get("pl").and_then(Json::as_arr).expect("pl candidates");
        assert!(!pl.is_empty(), "every node has at least one PL candidate");
        for cand in pl {
            assert!(cand.get("fmt").and_then(Json::as_str).is_some());
            assert!(cand.get("latency_us").and_then(Json::as_f64).unwrap() > 0.0);
        }
    }
    // Unknown combos are a clean protocol error, not a dead daemon.
    assert!(client.profile("dqn_tetris", 32, true).is_err());
    let stats = client.stats().unwrap();
    assert!(
        stats.get("latency_us").and_then(|l| l.get("profile")).is_some(),
        "per-verb latency must cover the profile verb: {stats}"
    );
    client.shutdown().unwrap();
    handle.join().unwrap();
}

/// FP32 vs quantized travel the wire as distinct plans, and the remote
/// side sees the same precision-dependent formats the local one does.
#[test]
fn remote_respects_precision_mode() {
    let (addr, handle) = boot(2);
    let client = RemotePlanner::connect(&addr).unwrap();
    let quant = client.plan_named("ddpg_lunar", 96, true).unwrap();
    let fp32 = client.plan_named("ddpg_lunar", 96, false).unwrap();
    assert!(quant.quantized && !fp32.quantized);
    assert!(
        fp32.schedule.iter().all(|e| e.format == "FP32"),
        "FP32 control must not carry reduced-precision formats"
    );
    let local_q = static_phase(&combo("ddpg_lunar"), 96, true);
    assert_eq!(quant.makespan_us.to_bits(), local_q.schedule.makespan_us.to_bits());
    let local_f = static_phase(&combo("ddpg_lunar"), 96, false);
    assert_eq!(fp32.makespan_us.to_bits(), local_f.schedule.makespan_us.to_bits());
    client.shutdown().unwrap();
    handle.join().unwrap();
}
