//! The observability layer end to end — the contract `apdrl dash`
//! depends on:
//!
//! * the bounded ring drops oldest and never blocks a publisher, even
//!   with concurrent publishers on `exec::pool` threads;
//! * the SSE endpoint emits frames a plain line client can parse back,
//!   and feeds any number of concurrent subscribers;
//! * token auth rejects bad/missing tokens and refuses non-loopback
//!   binds without one;
//! * `/emit` ingest round-trips into `/snapshot`, which is how the
//!   [`Forwarder`] relays a producer's bus into a remote dash;
//! * a live subscriber never perturbs training: a DQN-CartPole run with
//!   the global bus hot is bit-identical to one without.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use apdrl::coordinator::config::ComboConfig;
use apdrl::coordinator::metrics::RunMetrics;
use apdrl::coordinator::{train_combo, TrainLimits};
use apdrl::exec::{CpuBackend, Pool};
use apdrl::graph::{Algo, NetSpec};
use apdrl::obs::{Bus, DashServer, Event, Forwarder};
use apdrl::util::json::Json;

const CLIENT_TIMEOUT: Duration = Duration::from_secs(10);

/// Bind a dash on an ephemeral loopback port and run it on a thread.
fn start_dash(bus: Arc<Bus>, token: Option<&str>) -> (SocketAddr, Arc<AtomicBool>, JoinHandle<()>) {
    let server =
        DashServer::bind("127.0.0.1:0", bus, token.map(str::to_string)).expect("dash must bind");
    let addr = server.local_addr().expect("dash must report its address");
    let flag = server.shutdown_flag();
    let handle = std::thread::spawn(move || {
        server.run().expect("dash run loop must exit cleanly");
    });
    (addr, flag, handle)
}

fn stop_dash(flag: &AtomicBool, handle: JoinHandle<()>) {
    flag.store(true, Ordering::SeqCst);
    handle.join().expect("dash thread must join");
}

/// Read one HTTP/1.1 response: status line, headers, content-length
/// body. Works for both close and keep-alive responses.
fn read_http_response(reader: &mut BufReader<TcpStream>) -> (String, String) {
    let mut status = String::new();
    reader.read_line(&mut status).expect("response status line");
    let mut length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).expect("response header line");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((key, value)) = line.split_once(':') {
            if key.trim().eq_ignore_ascii_case("content-length") {
                length = value.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; length];
    reader.read_exact(&mut body).expect("response body");
    (status.trim_end().to_string(), String::from_utf8(body).expect("UTF-8 body"))
}

fn http_get(addr: &SocketAddr, target: &str, extra_header: Option<&str>) -> (String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect to dash");
    stream.set_read_timeout(Some(CLIENT_TIMEOUT)).expect("read timeout");
    let extra = extra_header.map(|h| format!("{h}\r\n")).unwrap_or_default();
    let request = format!("GET {target} HTTP/1.1\r\nHost: dash\r\n{extra}\r\n");
    stream.write_all(request.as_bytes()).expect("send request");
    let mut reader = BufReader::new(stream);
    read_http_response(&mut reader)
}

/// A minimal `text/event-stream` client: handshake, then parse
/// `event:`/`data:` frames, skipping `retry:` and `: ping` noise.
struct SseClient {
    reader: BufReader<TcpStream>,
}

impl SseClient {
    fn connect(addr: &SocketAddr) -> SseClient {
        let mut stream = TcpStream::connect(addr).expect("connect to dash");
        stream.set_read_timeout(Some(CLIENT_TIMEOUT)).expect("read timeout");
        stream.write_all(b"GET /events HTTP/1.1\r\nHost: dash\r\n\r\n").expect("send SSE request");
        let mut reader = BufReader::new(stream);
        let mut status = String::new();
        reader.read_line(&mut status).expect("SSE status line");
        assert!(status.contains("200 OK"), "SSE handshake refused: {status}");
        let mut saw_content_type = false;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).expect("SSE header line");
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            saw_content_type |= line.to_ascii_lowercase().contains("text/event-stream");
        }
        assert!(saw_content_type, "SSE response must declare text/event-stream");
        SseClient { reader }
    }

    fn next_frames(&mut self, n: usize) -> Vec<(String, Json)> {
        let mut frames = Vec::new();
        let mut kind: Option<String> = None;
        while frames.len() < n {
            let mut line = String::new();
            self.reader.read_line(&mut line).expect("SSE frame line");
            let line = line.trim_end_matches('\n');
            if let Some(k) = line.strip_prefix("event: ") {
                kind = Some(k.to_string());
            } else if let Some(d) = line.strip_prefix("data: ") {
                let k = kind.take().expect("data line must follow an event line");
                let data = Json::parse(d).expect("SSE data must be one line of JSON");
                frames.push((k, data));
            }
        }
        frames
    }
}

#[test]
fn ring_overflow_drops_oldest_and_never_blocks_publishers() {
    let bus = Bus::with_capacity(8);
    let mut sub = bus.subscribe();
    // 20 publishes into an 8-slot ring: all return instantly, the 12
    // oldest fall off the front.
    for i in 0..20 {
        bus.publish(Event::new("ovf").num("i", i as f64));
    }
    let drained = sub.drain();
    assert_eq!(drained.dropped, 12);
    assert_eq!(drained.events.len(), 8);
    let seqs: Vec<u64> = drained.events.iter().map(|e| e.seq).collect();
    assert_eq!(seqs, (12..20).collect::<Vec<u64>>());
    let payload: Vec<usize> =
        drained.events.iter().map(|e| e.fields["i"].as_usize().expect("i field")).collect();
    assert_eq!(payload, (12..20).collect::<Vec<usize>>());
    // A later drain starts clean.
    let again = sub.drain();
    assert_eq!(again.dropped, 0);
    assert!(again.events.is_empty());
}

#[test]
fn concurrent_publishers_on_pool_threads_lose_nothing_below_capacity() {
    let bus = Bus::with_capacity(1024);
    let mut sub = bus.subscribe();
    let pool = Pool::new(4);
    pool.run(256, &|i| {
        bus.publish(Event::new("pool.evt").num("task", i as f64));
    });
    let drained = sub.drain();
    assert_eq!(drained.dropped, 0);
    assert_eq!(drained.events.len(), 256);
    for (k, event) in drained.events.iter().enumerate() {
        assert_eq!(event.seq, k as u64, "sequence numbers stay contiguous under contention");
    }
    let mut tasks: Vec<usize> =
        drained.events.iter().map(|e| e.fields["task"].as_usize().expect("task field")).collect();
    tasks.sort_unstable();
    assert_eq!(tasks, (0..256).collect::<Vec<usize>>(), "every task's event arrived exactly once");
}

#[test]
fn sse_frames_parse_back_with_kind_and_one_line_json_payload() {
    let bus = Bus::with_capacity(64);
    let (addr, flag, handle) = start_dash(Arc::clone(&bus), None);
    let mut client = SseClient::connect(&addr);
    // The stream subscribes before its headers go out, so everything
    // published from here on is guaranteed to reach the client.
    bus.publish(Event::new("train.episode").num("reward", 31.5).num("lane", 1.0));
    bus.publish(Event::new("train.scale").tag("from", "65536").tag("to", "32768"));
    let frames = client.next_frames(2);
    assert_eq!(frames[0].0, "train.episode");
    assert_eq!(frames[0].1.get("reward").and_then(Json::as_f64), Some(31.5));
    assert_eq!(frames[0].1.get("kind").and_then(Json::as_str), Some("train.episode"));
    assert!(frames[0].1.get("seq").and_then(Json::as_f64).is_some());
    assert_eq!(frames[1].0, "train.scale");
    assert_eq!(frames[1].1.get("to").and_then(Json::as_str), Some("32768"));
    stop_dash(&flag, handle);
}

#[test]
fn two_concurrent_subscribers_both_see_events_from_all_three_sources() {
    let bus = Bus::with_capacity(64);
    let (addr, flag, handle) = start_dash(Arc::clone(&bus), None);
    let mut first = SseClient::connect(&addr);
    let mut second = SseClient::connect(&addr);
    // One event per producer family: trainer, planner, federation.
    bus.publish(Event::new("train.episode").num("reward", 12.0).num("episode", 4.0));
    bus.publish(Event::new("sweep.point").num("done", 3.0).num("total", 8.0));
    bus.publish(Event::new("fed.shard").tag("host", "h0").num("wall_us", 120.0));
    for client in [&mut first, &mut second] {
        let frames = client.next_frames(3);
        let kinds: Vec<&str> = frames.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(kinds, ["train.episode", "sweep.point", "fed.shard"]);
        assert_eq!(frames[0].1.get("reward").and_then(Json::as_f64), Some(12.0));
        assert_eq!(frames[1].1.get("total").and_then(Json::as_f64), Some(8.0));
        assert_eq!(frames[2].1.get("host").and_then(Json::as_str), Some("h0"));
    }
    stop_dash(&flag, handle);
}

#[test]
fn token_auth_rejects_bad_or_missing_tokens() {
    let bus = Bus::with_capacity(64);
    let (addr, flag, handle) = start_dash(Arc::clone(&bus), Some("sekrit"));
    let (denied, _) = http_get(&addr, "/snapshot", None);
    assert!(denied.starts_with("HTTP/1.1 401"), "missing token must 401, got: {denied}");
    let (wrong, _) = http_get(&addr, "/snapshot?token=nope", None);
    assert!(wrong.starts_with("HTTP/1.1 401"), "bad token must 401, got: {wrong}");
    let (via_query, _) = http_get(&addr, "/snapshot?token=sekrit", None);
    assert!(via_query.starts_with("HTTP/1.1 200"), "query token must pass, got: {via_query}");
    let (via_bearer, _) = http_get(&addr, "/snapshot", Some("Authorization: Bearer sekrit"));
    assert!(via_bearer.starts_with("HTTP/1.1 200"), "bearer token must pass, got: {via_bearer}");
    stop_dash(&flag, handle);
}

#[test]
fn nonloopback_bind_without_a_token_is_refused() {
    let err = match DashServer::bind("0.0.0.0:0", Bus::with_capacity(8), None) {
        Ok(_) => panic!("non-loopback bind without a token must be refused"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("token"), "error must explain the fix: {err:#}");
    // The same bind with a token is fine.
    let server = DashServer::bind("0.0.0.0:0", Bus::with_capacity(8), Some("sekrit".to_string()))
        .expect("non-loopback bind with a token must succeed");
    drop(server);
}

#[test]
fn emit_ingest_round_trips_into_the_snapshot_view() {
    let bus = Bus::with_capacity(64);
    let (addr, flag, handle) = start_dash(Arc::clone(&bus), None);

    let body = concat!(
        r#"{"events":[{"kind":"train.episode","reward":12.5,"lane":0},"#,
        r#"{"kind":"plan.cache","hit":true}]}"#
    );
    let mut stream = TcpStream::connect(addr).expect("connect to dash");
    stream.set_read_timeout(Some(CLIENT_TIMEOUT)).expect("read timeout");
    let request = format!(
        "POST /emit HTTP/1.1\r\nHost: dash\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(request.as_bytes()).expect("send emit");
    let mut reader = BufReader::new(stream.try_clone().expect("clone emit socket"));
    let (status, response) = read_http_response(&mut reader);
    assert!(status.starts_with("HTTP/1.1 200"), "emit must succeed, got: {status}");
    assert!(response.contains("\"accepted\":2"), "got: {response}");

    // The connection is keep-alive: a malformed second batch answers
    // 400 on the same socket without desynchronizing it.
    let garbage = "not json at all";
    let request = format!(
        "POST /emit HTTP/1.1\r\nHost: dash\r\nContent-Length: {}\r\n\r\n{garbage}",
        garbage.len()
    );
    stream.write_all(request.as_bytes()).expect("send bad emit");
    let (status, _) = read_http_response(&mut reader);
    assert!(status.starts_with("HTTP/1.1 400"), "garbage must 400, got: {status}");

    let (status, snapshot) = http_get(&addr, "/snapshot", None);
    assert!(status.starts_with("HTTP/1.1 200"), "got: {status}");
    let snap = Json::parse(&snapshot).expect("snapshot must be JSON");
    let events = snap.get("events").and_then(Json::as_arr).expect("events array");
    let kinds: Vec<&str> =
        events.iter().filter_map(|e| e.get("kind").and_then(Json::as_str)).collect();
    assert_eq!(kinds, ["train.episode", "plan.cache"]);
    let episode = &events[0];
    assert_eq!(episode.get("reward").and_then(Json::as_f64), Some(12.5));
    assert!(episode.get("seq").and_then(Json::as_f64).is_some(), "dash assigns seq on ingest");
    stop_dash(&flag, handle);
}

#[test]
fn forwarder_relays_the_global_bus_into_a_remote_dash() {
    let bus = Bus::with_capacity(1024);
    let (addr, flag, handle) = start_dash(Arc::clone(&bus), None);
    let forwarder = Forwarder::start(&addr.to_string(), None);
    // The kind is unique to this test: the global bus is shared across
    // the whole test binary, so the snapshot may carry other events.
    apdrl::obs::publish(Event::new("test.forward.unique").num("x", 7.0));
    forwarder.finish();
    let (status, snapshot) = http_get(&addr, "/snapshot", None);
    assert!(status.starts_with("HTTP/1.1 200"), "got: {status}");
    let snap = Json::parse(&snapshot).expect("snapshot must be JSON");
    let events = snap.get("events").and_then(Json::as_arr).expect("events array");
    let relayed = events
        .iter()
        .find(|e| e.get("kind").and_then(Json::as_str) == Some("test.forward.unique"))
        .expect("the forwarded event must reach the dash before finish() returns");
    assert_eq!(relayed.get("x").and_then(Json::as_f64), Some(7.0));
    stop_dash(&flag, handle);
}

fn bits(values: &[f64]) -> Vec<u64> {
    values.iter().map(|v| v.to_bits()).collect()
}

fn train_dqn_cartpole() -> RunMetrics {
    let combo = ComboConfig {
        name: "dqn_obs_pin",
        algo: Algo::Dqn,
        env: "cartpole",
        net: NetSpec::mlp(&[4, 24, 2]),
        batch: 16,
        obs_dim: 4,
        act_dim: 2,
        paper_flops_per_row: 0.0,
        paper_reward_error_pct: 0.0,
    };
    let limits = TrainLimits { max_env_steps: 600, max_episodes: 10_000 };
    let mut backend = CpuBackend::fp32().with_warmup(32).with_train_every(4);
    train_combo(&mut backend, &combo, 1, limits, false).expect("training must run").metrics
}

/// Acceptance pin: events observe only — no RNG draws, no training
/// state — so a live subscriber on the global bus cannot perturb a run.
#[test]
fn training_with_a_live_subscriber_is_bit_identical_to_training_without() {
    let quiet = train_dqn_cartpole();
    let observed = {
        let _watch = apdrl::obs::global().subscribe();
        train_dqn_cartpole()
    };
    assert_eq!(bits(&quiet.episode_rewards), bits(&observed.episode_rewards));
    assert_eq!(bits(&quiet.losses), bits(&observed.losses));
    assert_eq!(quiet.env_steps, observed.env_steps);
    assert_eq!(quiet.train_steps, observed.train_steps);
    assert_eq!(quiet.overflows, observed.overflows);
    assert_eq!(quiet.scale_transitions, observed.scale_transitions);
    assert_eq!(quiet.final_loss_scale.to_bits(), observed.final_loss_scale.to_bits());
}
