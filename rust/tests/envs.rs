//! Seeded determinism of every environment: `apdrl train` reproducibility
//! rests on the env stream being a pure function of the seed, so for
//! each env the same seed must give a *bit-identical* 200-step
//! transition stream (observations, rewards, done flags), and a
//! different seed must diverge.

use apdrl::envs::{
    Action, BatchedEnv, CartPole, Env, InvertedPendulum, LunarLanderCont, MiniBreakout,
    MiniMsPacman, MountainCarCont,
};
use apdrl::exec::Pool;
use apdrl::util::Rng;

/// Drive `env` for 200 steps (resetting on done) with seed-derived
/// randomness; returns the full bit-level transition stream.
fn stream(env: &mut dyn Env, seed: u64) -> Vec<(Vec<u32>, u64, bool)> {
    let mut rng = Rng::new(seed);
    let mut act_rng = rng.fork(0xAC7);
    let mut out = Vec::with_capacity(200);
    let mut _obs = env.reset(&mut rng);
    for _ in 0..200 {
        let action = if env.is_discrete() {
            Action::Discrete(act_rng.below(env.action_dim()))
        } else {
            Action::Continuous(
                (0..env.action_dim())
                    .map(|_| act_rng.uniform_in(-1.0, 1.0) as f32)
                    .collect(),
            )
        };
        let tr = env.step(&action, &mut rng);
        out.push((
            tr.obs.iter().map(|x| x.to_bits()).collect(),
            tr.reward.to_bits(),
            tr.done,
        ));
        if tr.done {
            _obs = env.reset(&mut rng);
        } else {
            _obs = tr.obs;
        }
    }
    out
}

fn fresh_envs() -> Vec<(&'static str, Box<dyn Env>)> {
    vec![
        ("cartpole", Box::new(CartPole::new()) as Box<dyn Env>),
        ("invpendulum", Box::new(InvertedPendulum::new())),
        ("lunarcont", Box::new(LunarLanderCont::new())),
        ("mntncarcont", Box::new(MountainCarCont::new())),
        ("breakout_mini", Box::new(MiniBreakout::mini())),
        ("mspacman_mini", Box::new(MiniMsPacman::mini())),
        ("breakout_full", Box::new(MiniBreakout::full())),
        ("mspacman_full", Box::new(MiniMsPacman::full())),
    ]
}

#[test]
fn same_seed_gives_bit_identical_200_step_streams() {
    for seed in [1u64, 77] {
        let mut first = fresh_envs();
        let mut second = fresh_envs();
        for ((name, a), (_, b)) in first.iter_mut().zip(second.iter_mut()) {
            let sa = stream(a.as_mut(), seed);
            let sb = stream(b.as_mut(), seed);
            assert_eq!(sa.len(), 200, "{name}");
            assert_eq!(sa, sb, "{name}: seed {seed} stream not bit-identical");
        }
    }
}

#[test]
fn different_seeds_diverge() {
    let mut first = fresh_envs();
    let mut second = fresh_envs();
    for ((name, a), (_, b)) in first.iter_mut().zip(second.iter_mut()) {
        let sa = stream(a.as_mut(), 1);
        let sb = stream(b.as_mut(), 2);
        // Observations must differ somewhere in 200 steps (rewards may
        // coincide — CartPole pays +1 per step).
        let obs_a: Vec<&Vec<u32>> = sa.iter().map(|(o, _, _)| o).collect();
        let obs_b: Vec<&Vec<u32>> = sb.iter().map(|(o, _, _)| o).collect();
        assert_ne!(obs_a, obs_b, "{name}: different seeds gave one stream");
    }
}

#[test]
fn fresh_instance_equals_reused_instance_after_reset() {
    // Determinism must not depend on construction-time state: a reused
    // env re-seeded from scratch replays the same stream.
    let mut reused = fresh_envs();
    for (name, env) in reused.iter_mut() {
        let a = stream(env.as_mut(), 9);
        let b = stream(env.as_mut(), 9);
        assert_eq!(a, b, "{name}: reused instance diverged from its own seed-9 stream");
    }
}

/// `BatchedEnv` determinism: a fleet of N seeded lanes must replay N
/// independent scalar envs *bit-for-bit* — raw transitions, rewards,
/// done flags and the post-auto-reset observations — for every env in
/// the registry.  This is the env half of the `--actors 1` bit-identity
/// guarantee, checked at every lane (not just lane 0) so the pool
/// fan-out can never leak state across lanes.
#[test]
fn batched_lanes_equal_independent_scalar_envs() {
    const LANES: usize = 3;
    const STEPS: usize = 220;
    let registry = fresh_envs();
    for (i, (name, _)) in registry.iter().enumerate() {
        let envs: Vec<Box<dyn Env>> = (0..LANES).map(|_| fresh_envs().swap_remove(i).1).collect();
        let rngs: Vec<Rng> = (0..LANES).map(|l| Rng::new(1_000 + l as u64)).collect();
        let mut fleet = BatchedEnv::new(envs, rngs, Pool::global()).expect("fleet");
        let d = fleet.obs_dim();

        // Scalar twins: same env kind, same per-lane RNG streams.
        let mut scalars: Vec<(Box<dyn Env>, Rng, Vec<f32>)> = (0..LANES)
            .map(|l| {
                let mut env = fresh_envs().swap_remove(i).1;
                let mut rng = Rng::new(1_000 + l as u64);
                let cur = env.reset(&mut rng);
                (env, rng, cur)
            })
            .collect();
        for (l, (_, _, cur)) in scalars.iter().enumerate() {
            assert_eq!(fleet.obs()[l * d..(l + 1) * d], cur[..], "{name}: lane {l} reset obs");
        }

        let mut act_rng = Rng::new(9);
        let mut dones_seen = 0usize;
        for step in 0..STEPS {
            let actions: Vec<Action> = (0..LANES)
                .map(|_| {
                    if fleet.is_discrete() {
                        Action::Discrete(act_rng.below(fleet.action_dim()))
                    } else {
                        Action::Continuous(
                            (0..fleet.action_dim())
                                .map(|_| act_rng.uniform_in(-1.0, 1.0) as f32)
                                .collect(),
                        )
                    }
                })
                .collect();
            fleet.step(&actions).expect("step");
            for l in 0..LANES {
                let (env, rng, cur) = &mut scalars[l];
                let tr = env.step(&actions[l], rng);
                assert_eq!(
                    fleet.next_obs()[l * d..(l + 1) * d],
                    tr.obs[..],
                    "{name} lane {l} step {step}: raw next_obs diverged"
                );
                assert_eq!(
                    fleet.rewards()[l].to_bits(),
                    tr.reward.to_bits(),
                    "{name} lane {l} step {step}: reward diverged"
                );
                assert_eq!(
                    fleet.dones()[l],
                    tr.done,
                    "{name} lane {l} step {step}: done flag diverged"
                );
                *cur = if tr.done {
                    dones_seen += 1;
                    env.reset(rng)
                } else {
                    tr.obs
                };
                assert_eq!(
                    fleet.obs()[l * d..(l + 1) * d],
                    cur[..],
                    "{name} lane {l} step {step}: post-auto-reset obs diverged"
                );
            }
        }
        if *name == "cartpole" {
            assert!(dones_seen > 0, "cartpole fleet must auto-reset within {STEPS} random steps");
        }
    }
}
