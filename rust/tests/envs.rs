//! Seeded determinism of every environment: `apdrl train` reproducibility
//! rests on the env stream being a pure function of the seed, so for
//! each env the same seed must give a *bit-identical* 200-step
//! transition stream (observations, rewards, done flags), and a
//! different seed must diverge.

use apdrl::envs::{
    Action, CartPole, Env, InvertedPendulum, LunarLanderCont, MiniBreakout, MiniMsPacman,
    MountainCarCont,
};
use apdrl::util::Rng;

/// Drive `env` for 200 steps (resetting on done) with seed-derived
/// randomness; returns the full bit-level transition stream.
fn stream(env: &mut dyn Env, seed: u64) -> Vec<(Vec<u32>, u64, bool)> {
    let mut rng = Rng::new(seed);
    let mut act_rng = rng.fork(0xAC7);
    let mut out = Vec::with_capacity(200);
    let mut _obs = env.reset(&mut rng);
    for _ in 0..200 {
        let action = if env.is_discrete() {
            Action::Discrete(act_rng.below(env.action_dim()))
        } else {
            Action::Continuous(
                (0..env.action_dim())
                    .map(|_| act_rng.uniform_in(-1.0, 1.0) as f32)
                    .collect(),
            )
        };
        let tr = env.step(&action, &mut rng);
        out.push((
            tr.obs.iter().map(|x| x.to_bits()).collect(),
            tr.reward.to_bits(),
            tr.done,
        ));
        if tr.done {
            _obs = env.reset(&mut rng);
        } else {
            _obs = tr.obs;
        }
    }
    out
}

fn fresh_envs() -> Vec<(&'static str, Box<dyn Env>)> {
    vec![
        ("cartpole", Box::new(CartPole::new()) as Box<dyn Env>),
        ("invpendulum", Box::new(InvertedPendulum::new())),
        ("lunarcont", Box::new(LunarLanderCont::new())),
        ("mntncarcont", Box::new(MountainCarCont::new())),
        ("breakout_mini", Box::new(MiniBreakout::mini())),
        ("mspacman_mini", Box::new(MiniMsPacman::mini())),
        ("breakout_full", Box::new(MiniBreakout::full())),
        ("mspacman_full", Box::new(MiniMsPacman::full())),
    ]
}

#[test]
fn same_seed_gives_bit_identical_200_step_streams() {
    for seed in [1u64, 77] {
        let mut first = fresh_envs();
        let mut second = fresh_envs();
        for ((name, a), (_, b)) in first.iter_mut().zip(second.iter_mut()) {
            let sa = stream(a.as_mut(), seed);
            let sb = stream(b.as_mut(), seed);
            assert_eq!(sa.len(), 200, "{name}");
            assert_eq!(sa, sb, "{name}: seed {seed} stream not bit-identical");
        }
    }
}

#[test]
fn different_seeds_diverge() {
    let mut first = fresh_envs();
    let mut second = fresh_envs();
    for ((name, a), (_, b)) in first.iter_mut().zip(second.iter_mut()) {
        let sa = stream(a.as_mut(), 1);
        let sb = stream(b.as_mut(), 2);
        // Observations must differ somewhere in 200 steps (rewards may
        // coincide — CartPole pays +1 per step).
        let obs_a: Vec<&Vec<u32>> = sa.iter().map(|(o, _, _)| o).collect();
        let obs_b: Vec<&Vec<u32>> = sb.iter().map(|(o, _, _)| o).collect();
        assert_ne!(obs_a, obs_b, "{name}: different seeds gave one stream");
    }
}

#[test]
fn fresh_instance_equals_reused_instance_after_reset() {
    // Determinism must not depend on construction-time state: a reused
    // env re-seeded from scratch replays the same stream.
    let mut reused = fresh_envs();
    for (name, env) in reused.iter_mut() {
        let a = stream(env.as_mut(), 9);
        let b = stream(env.as_mut(), 9);
        assert_eq!(a, b, "{name}: reused instance diverged from its own seed-9 stream");
    }
}
